//! Flight recorder: a bounded per-store ring buffer of structured
//! tier-transition events, exportable as Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto) via `--trace-out`.
//!
//! Every mutation of a frozen row's residency is recorded with the
//! step it happened on, the tiers it moved between, and *why*
//! (freeze/expire/pressure/prefetch/restore/recover/emergency/drop),
//! so a single decode trace shows exactly why a row moved and what
//! each step waited on. The cause taxonomy is count-reconcilable
//! against the store's conservation counters (see
//! `tests/telemetry.rs`):
//!
//! * `Freeze` + `Recover` events  == `total_stashed`
//! * `Restore` + `Emergency` events == `total_restored`
//! * `Drop` + `Supersede` events  == `total_dropped`
//!
//! The speculative restore pipeline's lifecycle causes (`SpecIssue` /
//! `SpecLand` / `SpecCancel`, plus the bounded-wait `RestoreTimeout`)
//! sit deliberately outside those groups: speculation is a cache fill,
//! not a tier transition, so it must not perturb the conservation
//! reconciliation. They render on their own trace track so overlap
//! with the decode-step track is visible.

use std::collections::VecDeque;
use std::time::Instant;

use super::TierKind;
use crate::util::json::Json;

/// Process-global monotonic microsecond clock shared by the flight
/// recorder and the engine's step-segment timing, so trace tracks and
/// decode-step spans land on one timebase.
pub fn now_us() -> u64 {
    static EPOCH: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);
    EPOCH.elapsed().as_micros() as u64
}

/// Why a row moved (or left) a tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cause {
    /// plan-driven freeze of an active row into the store
    Freeze,
    /// plan-driven restore back into the active window
    Restore,
    /// thaw-eta expiry swept the row hot -> cold
    Expire,
    /// byte-budget pressure demoted the row
    Pressure,
    /// prefetch staged the row into the hot tier ahead of its eta
    Prefetch,
    /// adopted from a persistent spill file at resume
    Recover,
    /// emergency drain (recovery rewalk) pulled the row out
    Emergency,
    /// row discarded without restore
    Drop,
    /// stale recovered copy superseded by a fresh freeze
    Supersede,
    /// speculative restore submitted to the worker pool
    SpecIssue,
    /// speculative restore landed in the staging buffer
    SpecLand,
    /// speculative restore cancelled (superseded row, stale
    /// generation, or deadline expiry before consumption)
    SpecCancel,
    /// a take's bounded wait on an in-flight speculative reply
    /// expired (`--restore-wait-timeout-ms`): the take failed typed
    /// instead of blocking on a dead or delayed shard
    RestoreTimeout,
}

impl Cause {
    pub fn as_str(&self) -> &'static str {
        match self {
            Cause::Freeze => "freeze",
            Cause::Restore => "restore",
            Cause::Expire => "expire",
            Cause::Pressure => "pressure",
            Cause::Prefetch => "prefetch",
            Cause::Recover => "recover",
            Cause::Emergency => "emergency",
            Cause::Drop => "drop",
            Cause::Supersede => "supersede",
            Cause::SpecIssue => "spec-issue",
            Cause::SpecLand => "spec-land",
            Cause::SpecCancel => "spec-cancel",
            Cause::RestoreTimeout => "restore_timeout",
        }
    }

    /// Whether this is a speculative-pipeline lifecycle event (rendered
    /// on the dedicated speculative trace track, excluded from the
    /// conservation reconciliation).
    pub fn is_spec(&self) -> bool {
        matches!(
            self,
            Cause::SpecIssue | Cause::SpecLand | Cause::SpecCancel | Cause::RestoreTimeout
        )
    }
}

/// One recorded tier transition. `from`/`to` of `None` mean the active
/// window (freeze enters the store, restore/drop leave it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlightEvent {
    /// monotonic per-recorder sequence number (never reset, so a
    /// wrapped ring still exposes how much history was lost)
    pub seq: u64,
    /// microseconds on the shared [`now_us`] timebase
    pub ts_us: u64,
    /// decode step the store last observed
    pub step: u64,
    /// sequence position of the row
    pub pos: usize,
    pub from: Option<TierKind>,
    pub to: Option<TierKind>,
    pub cause: Cause,
    /// predicted thaw step of the row at event time
    pub eta: u64,
}

/// Bounded ring buffer of [`FlightEvent`]s. Capacity 0 disables
/// recording entirely (every event counts as dropped).
#[derive(Debug, Clone, Default)]
pub struct FlightRecorder {
    cap: usize,
    buf: VecDeque<FlightEvent>,
    next_seq: u64,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        FlightRecorder { cap, buf: VecDeque::with_capacity(cap.min(1024)), ..Default::default() }
    }

    /// Record one transition; evicts the oldest event when full.
    pub fn record(
        &mut self,
        step: u64,
        pos: usize,
        from: Option<TierKind>,
        to: Option<TierKind>,
        cause: Cause,
        eta: u64,
    ) {
        let ev = FlightEvent { seq: self.next_seq, ts_us: now_us(), step, pos, from, to, cause, eta };
        self.next_seq += 1;
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events evicted (or suppressed by a zero capacity).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events recorded over the recorder's lifetime, including evicted ones.
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &FlightEvent> {
        self.buf.iter()
    }
}

/// Per-step segment attribution used for the trace's decode-step
/// track: five sequential `ph:"X"` spans (plan -> restore -> restore
/// wait -> freeze -> compute) anchored at the step's start time. Built
/// by the engine from its per-step trace records. `restore_wait_us` is
/// the time the step spent *blocked* reclaiming speculative pipeline
/// jobs — with the pipeline doing its job it stays near zero while the
/// speculative track shows the same I/O overlapping compute.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepSpan {
    pub step: u64,
    pub start_us: u64,
    pub plan_us: u64,
    pub restore_us: u64,
    pub restore_wait_us: u64,
    pub freeze_us: u64,
    pub compute_us: u64,
}

fn tier_tid(t: TierKind) -> u64 {
    match t {
        TierKind::Hot => 1,
        TierKind::Cold => 2,
        TierKind::Spill => 3,
    }
}

const STEP_TID: u64 = 50;
/// Track for speculative-pipeline lifecycle events, adjacent to the
/// decode-step track so issue/land/cancel visually bracket the steps
/// whose I/O they overlap.
const SPEC_TID: u64 = 60;
const SHARD_TID_BASE: u64 = 100;

fn meta_event(tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("thread_name")),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn instant_event(tid: u64, ev: &FlightEvent, shard: usize) -> Json {
    let from = ev.from.map(|t| t.as_str()).unwrap_or("active");
    let to = ev.to.map(|t| t.as_str()).unwrap_or("active");
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("name", Json::str(format!("{} pos {} {}->{}", ev.cause.as_str(), ev.pos, from, to))),
        ("cat", Json::str(ev.cause.as_str())),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(ev.ts_us as f64)),
        (
            "args",
            Json::obj(vec![
                ("pos", Json::num(ev.pos as f64)),
                ("step", Json::num(ev.step as f64)),
                ("shard", Json::num(shard as f64)),
                ("from", Json::str(from)),
                ("to", Json::str(to)),
                ("eta", Json::num(ev.eta as f64)),
                ("seq", Json::num(ev.seq as f64)),
            ]),
        ),
    ])
}

fn duration_event(name: &str, ts: u64, dur: u64, step: u64) -> Json {
    Json::obj(vec![
        ("ph", Json::str("X")),
        ("name", Json::str(name)),
        ("cat", Json::str("step")),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(STEP_TID as f64)),
        ("ts", Json::num(ts as f64)),
        ("dur", Json::num(dur as f64)),
        ("args", Json::obj(vec![("step", Json::num(step as f64))])),
    ])
}

/// Write a Chrome trace-event JSON file: one instant-event track per
/// tier (the destination tier of each transition; the source tier for
/// events leaving the store), one track per shard, a speculative
/// pipeline track (issue/land/cancel instants, so the overlap with the
/// decode-step track is visible), and one duration-event track with
/// the per-step plan/restore/restore-wait/freeze/compute segments.
/// Events are `(shard, event)` pairs as returned by
/// `ShardedStore::flight_events`.
pub fn write_chrome_trace(
    path: &str,
    events: &[(usize, FlightEvent)],
    steps: &[StepSpan],
) -> std::io::Result<()> {
    let mut trace = Vec::new();
    trace.push(meta_event(tier_tid(TierKind::Hot), "tier hot"));
    trace.push(meta_event(tier_tid(TierKind::Cold), "tier cold"));
    trace.push(meta_event(tier_tid(TierKind::Spill), "tier spill"));
    trace.push(meta_event(STEP_TID, "decode steps"));
    if events.iter().any(|(_, ev)| ev.cause.is_spec()) {
        trace.push(meta_event(SPEC_TID, "speculative restores"));
    }
    let mut shards: Vec<usize> = events.iter().map(|(s, _)| *s).collect();
    shards.sort_unstable();
    shards.dedup();
    for &s in &shards {
        trace.push(meta_event(SHARD_TID_BASE + s as u64, &format!("shard {s}")));
    }
    for (shard, ev) in events {
        if ev.cause.is_spec() {
            // pipeline lifecycle: one instant on the speculative track
            // (a spec event is not a tier transition, so it does not
            // duplicate onto the tier/shard reconciliation tracks)
            trace.push(instant_event(SPEC_TID, ev, *shard));
            continue;
        }
        if let Some(tier) = ev.to.or(ev.from) {
            trace.push(instant_event(tier_tid(tier), ev, *shard));
        }
        trace.push(instant_event(SHARD_TID_BASE + *shard as u64, ev, *shard));
    }
    for sp in steps {
        let mut ts = sp.start_us;
        for (name, dur) in [
            ("plan", sp.plan_us),
            ("restore", sp.restore_us),
            ("restore wait", sp.restore_wait_us),
            ("freeze", sp.freeze_us),
            ("compute", sp.compute_us),
        ] {
            if dur > 0 {
                trace.push(duration_event(name, ts, dur, sp.step));
            }
            ts += dur;
        }
    }
    let doc = Json::obj(vec![("traceEvents", Json::Arr(trace))]);
    let mut out = String::new();
    crate::util::json::write_json(&doc, &mut out);
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = FlightRecorder::new(4);
        for pos in 0..10usize {
            r.record(pos as u64, pos, None, Some(TierKind::Hot), Cause::Freeze, 8);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        assert_eq!(r.recorded(), 10);
        let kept: Vec<usize> = r.events().map(|e| e.pos).collect();
        assert_eq!(kept, vec![6, 7, 8, 9], "oldest events must be evicted first");
    }

    #[test]
    fn zero_capacity_disables_recording() {
        let mut r = FlightRecorder::new(0);
        r.record(0, 1, None, Some(TierKind::Hot), Cause::Freeze, 2);
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 1);
        assert_eq!(r.recorded(), 1);
    }

    #[test]
    fn events_are_seq_and_time_ordered() {
        let mut r = FlightRecorder::new(16);
        r.record(0, 3, None, Some(TierKind::Hot), Cause::Freeze, 5);
        r.record(1, 3, Some(TierKind::Hot), Some(TierKind::Cold), Cause::Pressure, 5);
        r.record(2, 3, Some(TierKind::Cold), None, Cause::Restore, 5);
        let evs: Vec<&FlightEvent> = r.events().collect();
        for w in evs.windows(2) {
            assert!(w[0].seq < w[1].seq);
            assert!(w[0].ts_us <= w[1].ts_us);
        }
        assert_eq!(evs[1].from, Some(TierKind::Hot));
        assert_eq!(evs[1].to, Some(TierKind::Cold));
        assert_eq!(evs[2].to, None);
    }
}
