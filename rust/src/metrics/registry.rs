//! Process-wide metrics registry: a declared catalog of named series
//! (counters / gauges / histograms with label sets), a cheap
//! `SnapshotBuilder` that producers publish into, and an immutable
//! `Snapshot` with JSON / Prometheus-text exposition.
//!
//! Design: producers (tier stores, sessions, the batch engine, bench
//! sections) keep their own local counters/histograms exactly as
//! before — publication is a *pull*: `TieredStore::publish`,
//! `Session::publish_to_registry`, … emit their current totals into a
//! builder. A per-store `snapshot()` is a fresh builder filled by one
//! store (so `OffloadSummary` is now a view over it), while
//! `Registry::global()` accumulates across sessions for the server's
//! `stats` request and the `--metrics-interval` summary line. The
//! full metric catalog is documented in `rust/src/metrics/README.md`.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use super::{CountHistogram, Histogram};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Catalog

/// Kind of a registered metric series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone accumulating count (`_total` suffix).
    Counter,
    /// Point-in-time value (set/overwritten on publish).
    Gauge,
    /// Log-bucketed latency histogram, microseconds.
    TimeHistogram,
    /// Power-of-two bucketed histogram over dimensionless counts.
    CountHistogram,
}

impl MetricKind {
    /// Prometheus `# TYPE` keyword.
    pub fn prometheus_type(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::TimeHistogram | MetricKind::CountHistogram => "summary",
        }
    }
}

/// Declared shape of one metric: the single source of truth the
/// exposition formats, the bench CSV schema, and the docs test against.
#[derive(Debug, Clone, Copy)]
pub struct MetricSpec {
    pub name: &'static str,
    pub kind: MetricKind,
    /// unit of the recorded value ("rows", "bytes", "us", "events", …)
    pub unit: &'static str,
    /// label keys this series may carry (subset-per-publisher allowed:
    /// e.g. per-shard stores attach `shard`, the serving-wide gauges
    /// published by the batch engine omit it)
    pub labels: &'static [&'static str],
    pub help: &'static str,
}

/// Every metric name this crate emits. `tests/telemetry.rs` checks the
/// bench CSV schema and the exposition output against this list.
pub const CATALOG: &[MetricSpec] = &[
    // -- tiered-store flow counters -------------------------------------
    MetricSpec {
        name: "asrkf_stash_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["shard"],
        help: "rows frozen into the tiered store (incl. spill-recovery adoptions)",
    },
    MetricSpec {
        name: "asrkf_restore_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["shard"],
        help: "frozen rows restored to the active window",
    },
    MetricSpec {
        name: "asrkf_drop_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["shard"],
        help: "frozen rows discarded without restore",
    },
    MetricSpec {
        name: "asrkf_staged_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["result", "shard"],
        help: "restores by staging outcome: hit = served from a prefetch-staged hot row, miss = inline dequantize/read",
    },
    MetricSpec {
        name: "asrkf_demotion_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["to", "shard"],
        help: "tier demotions by destination (hot->cold, cold->spill)",
    },
    MetricSpec {
        name: "asrkf_promotion_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["shard"],
        help: "prefetch promotions into the staged hot tier",
    },
    // -- speculative restore pipeline ------------------------------------
    MetricSpec {
        name: "asrkf_spec_issued_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "rows submitted as speculative restore reads to the worker pool",
    },
    MetricSpec {
        name: "asrkf_spec_landed_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "speculative reads that landed with a current generation",
    },
    MetricSpec {
        name: "asrkf_spec_cancelled_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "speculative reads discarded (superseded generation or past deadline)",
    },
    MetricSpec {
        name: "asrkf_spec_consumed_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "takes served from a landed speculative copy (no inline tier I/O)",
    },
    MetricSpec {
        name: "asrkf_late_arrivals_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "takes that blocked on a speculative read still in flight",
    },
    MetricSpec {
        name: "asrkf_recovered_rows_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["shard"],
        help: "rows adopted from a persistent spill file at resume",
    },
    MetricSpec {
        name: "asrkf_recovery_errors_total",
        kind: MetricKind::Counter,
        unit: "records",
        labels: &["shard"],
        help: "corrupt/torn/fenced spill records reclaimed (never served)",
    },
    MetricSpec {
        name: "asrkf_tier_rows_stored_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &["tier", "shard"],
        help: "rows admitted into each tier (stash + demotion arrivals)",
    },
    MetricSpec {
        name: "asrkf_tier_row_bytes_total",
        kind: MetricKind::Counter,
        unit: "bytes",
        labels: &["tier", "shard"],
        help: "encoded payload bytes admitted into each tier; divided by \
               asrkf_tier_rows_stored_total this is the tier's bytes/row \
               under the active codec ladder",
    },
    MetricSpec {
        name: "asrkf_shard_imbalance_total",
        kind: MetricKind::Counter,
        unit: "bursts",
        labels: &[],
        help: "restore bursts where one shard carried >= 2x its fair share",
    },
    MetricSpec {
        name: "asrkf_flight_events_dropped_total",
        kind: MetricKind::Counter,
        unit: "events",
        labels: &["shard"],
        help: "flight-recorder events evicted by the bounded ring buffer",
    },
    // -- tiered-store gauges --------------------------------------------
    MetricSpec {
        name: "asrkf_tier_rows",
        kind: MetricKind::Gauge,
        unit: "rows",
        labels: &["tier", "shard"],
        help: "resident frozen rows per tier (serving-wide series omit shard)",
    },
    MetricSpec {
        name: "asrkf_tier_bytes",
        kind: MetricKind::Gauge,
        unit: "bytes",
        labels: &["tier", "shard"],
        help: "resident bytes per tier",
    },
    MetricSpec {
        name: "asrkf_tier_peak_bytes",
        kind: MetricKind::Gauge,
        unit: "bytes",
        labels: &["tier", "shard"],
        help: "high-water-mark bytes per tier",
    },
    MetricSpec {
        name: "asrkf_uncompressed_bytes",
        kind: MetricKind::Gauge,
        unit: "bytes",
        labels: &["shard"],
        help: "f32 bytes the resident frozen rows would occupy uncompressed",
    },
    MetricSpec {
        name: "asrkf_codec_rows",
        kind: MetricKind::Gauge,
        unit: "rows",
        labels: &["tier", "codec", "shard"],
        help: "resident rows per tier broken down by codec rung \
               (raw | u8 | u4 | ebq) of the compression ladder",
    },
    MetricSpec {
        name: "asrkf_shard_rows",
        kind: MetricKind::Gauge,
        unit: "rows",
        labels: &["shard"],
        help: "frozen rows resident per shard (0 for a lost shard)",
    },
    MetricSpec {
        name: "asrkf_shards",
        kind: MetricKind::Gauge,
        unit: "shards",
        labels: &[],
        help: "configured shard count of the publishing store",
    },
    // -- latency histograms (microseconds) ------------------------------
    MetricSpec {
        name: "asrkf_restore_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["tier"],
        help: "restore (take) latency by serving tier, merged across shards",
    },
    MetricSpec {
        name: "asrkf_restore_overlap_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "in-worker service time of speculative restore reads (I/O hidden behind decode)",
    },
    MetricSpec {
        name: "asrkf_restore_wait_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "per-step time blocked waiting for in-flight speculative reads to land",
    },
    MetricSpec {
        name: "asrkf_spill_read_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "spill-file record read+verify latency",
    },
    MetricSpec {
        name: "asrkf_spill_write_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "spill-file record write latency",
    },
    MetricSpec {
        name: "asrkf_codec_encode_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["codec"],
        help: "ladder encode latency per row by codec rung",
    },
    MetricSpec {
        name: "asrkf_codec_decode_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["codec"],
        help: "ladder decode latency per row by codec rung",
    },
    MetricSpec {
        name: "asrkf_plan_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "policy plan+observe control-plane cost per decode step",
    },
    MetricSpec {
        name: "asrkf_step_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &[],
        help: "decode step wall-clock (apply_plan start -> absorb end)",
    },
    MetricSpec {
        name: "asrkf_step_segment_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["segment"],
        help: "per-step wall-clock attributed to plan|restore|restore_wait|compute|freeze",
    },
    MetricSpec {
        name: "asrkf_ttft_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["class"],
        help: "time to first token per served request (aggregate series omits class)",
    },
    MetricSpec {
        name: "asrkf_e2e_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["class"],
        help: "end-to-end latency per served request (aggregate series omits class)",
    },
    MetricSpec {
        name: "asrkf_queue_wait_us",
        kind: MetricKind::TimeHistogram,
        unit: "us",
        labels: &["class"],
        help: "time from submission to slot admission, per QoS class",
    },
    // -- count histograms ------------------------------------------------
    MetricSpec {
        name: "asrkf_sched_depth",
        kind: MetricKind::CountHistogram,
        unit: "rows",
        labels: &[],
        help: "thaw-scheduler frozen-queue depth sampled per step, merged across shards",
    },
    MetricSpec {
        name: "asrkf_restore_parallelism",
        kind: MetricKind::CountHistogram,
        unit: "shards",
        labels: &[],
        help: "shards engaged per restore burst",
    },
    MetricSpec {
        name: "asrkf_restore_batch",
        kind: MetricKind::CountHistogram,
        unit: "rows",
        labels: &[],
        help: "rows per non-empty restore batch",
    },
    MetricSpec {
        name: "asrkf_freeze_batch",
        kind: MetricKind::CountHistogram,
        unit: "rows",
        labels: &[],
        help: "rows per non-empty freeze batch",
    },
    MetricSpec {
        name: "asrkf_spec_inflight_depth",
        kind: MetricKind::CountHistogram,
        unit: "jobs",
        labels: &[],
        help: "shards with a speculative read in flight, sampled per pipeline advance",
    },
    MetricSpec {
        name: "asrkf_batch_occupancy",
        kind: MetricKind::CountHistogram,
        unit: "slots",
        labels: &[],
        help: "live slots per dispatched serving batch",
    },
    // -- engine batch counters -------------------------------------------
    MetricSpec {
        name: "asrkf_restore_batch_rows_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "rows moved frozen->active across all restore batches",
    },
    MetricSpec {
        name: "asrkf_restore_batch_spans_total",
        kind: MetricKind::Counter,
        unit: "spans",
        labels: &[],
        help: "contiguous spans the restore rows coalesced into",
    },
    MetricSpec {
        name: "asrkf_freeze_batch_rows_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "rows moved active->frozen across all freeze batches",
    },
    MetricSpec {
        name: "asrkf_freeze_batch_spans_total",
        kind: MetricKind::Counter,
        unit: "spans",
        labels: &[],
        help: "contiguous spans the freeze rows coalesced into",
    },
    // -- serving counters -------------------------------------------------
    MetricSpec {
        name: "asrkf_requests_completed_total",
        kind: MetricKind::Counter,
        unit: "requests",
        labels: &[],
        help: "requests completed by the batch engine",
    },
    MetricSpec {
        name: "asrkf_requests_rejected_total",
        kind: MetricKind::Counter,
        unit: "requests",
        labels: &[],
        help: "requests rejected at admission",
    },
    MetricSpec {
        name: "asrkf_admission_total",
        kind: MetricKind::Counter,
        unit: "requests",
        labels: &["class", "decision"],
        help: "admission decisions: accept (effective class) | shed | reject (requested class)",
    },
    MetricSpec {
        name: "asrkf_queue_depth",
        kind: MetricKind::Gauge,
        unit: "requests",
        labels: &["class"],
        help: "waiting requests per QoS class queue",
    },
    MetricSpec {
        name: "asrkf_class_occupancy",
        kind: MetricKind::Gauge,
        unit: "slots",
        labels: &["class"],
        help: "occupied serving slots per effective QoS class",
    },
    MetricSpec {
        name: "asrkf_tokens_generated_total",
        kind: MetricKind::Counter,
        unit: "tokens",
        labels: &[],
        help: "decode tokens generated",
    },
    MetricSpec {
        name: "asrkf_prefill_tokens_total",
        kind: MetricKind::Counter,
        unit: "tokens",
        labels: &[],
        help: "prompt tokens prefetched into the KV cache",
    },
    MetricSpec {
        name: "asrkf_batches_dispatched_total",
        kind: MetricKind::Counter,
        unit: "batches",
        labels: &[],
        help: "device decode batches dispatched",
    },
    // -- fault injection / shard supervision ------------------------------
    MetricSpec {
        name: "asrkf_faults_injected_total",
        kind: MetricKind::Counter,
        unit: "faults",
        labels: &["site", "shard"],
        help: "faults fired by the seeded injector, per injection site",
    },
    MetricSpec {
        name: "asrkf_io_retries_total",
        kind: MetricKind::Counter,
        unit: "retries",
        labels: &["op", "outcome", "shard"],
        help: "spill I/O retries beyond the first attempt: recovered | exhausted",
    },
    MetricSpec {
        name: "asrkf_shard_rebuilds_total",
        kind: MetricKind::Counter,
        unit: "rebuilds",
        labels: &[],
        help: "shards rebuilt from their spill slice after a worker panic",
    },
    MetricSpec {
        name: "asrkf_rows_lost_total",
        kind: MetricKind::Counter,
        unit: "rows",
        labels: &[],
        help: "rows declared lost by shard rebuilds (no spilled copy survived)",
    },
    MetricSpec {
        name: "asrkf_degraded_shards",
        kind: MetricKind::Gauge,
        unit: "shards",
        labels: &[],
        help: "shards currently lost or inside their post-rebuild warm-up window, \
               summed over occupied slots; admission discounts this capacity",
    },
    // -- bench harness -----------------------------------------------------
    MetricSpec {
        name: "asrkf_bench_section_us",
        kind: MetricKind::Gauge,
        unit: "us",
        labels: &["section"],
        help: "wall-clock of one bench section (host-only sweeps, CSV export, ...)",
    },
];

/// Look up the declared spec for a metric name.
pub fn spec_for(name: &str) -> Option<&'static MetricSpec> {
    CATALOG.iter().find(|s| s.name == name)
}

// ---------------------------------------------------------------------------
// Bench CSV schema (headers declared against the catalog so bench
// schemas cannot drift from the metric set — checked in CI)

/// One bench CSV column: the header string and the catalog metric the
/// column's value is derived from ("" for pure sweep dimensions like
/// the mode name or request count).
#[derive(Debug, Clone, Copy)]
pub struct CsvColumn {
    pub header: &'static str,
    pub metric: &'static str,
}

/// Column schema of `artifacts/serving_throughput.csv`. The bench
/// builds its table headers from this list; `tests/telemetry.rs`
/// asserts every referenced metric exists in [`CATALOG`].
pub const SERVING_CSV_COLUMNS: &[CsvColumn] = &[
    CsvColumn { header: "Mode", metric: "" },
    CsvColumn { header: "Shards", metric: "asrkf_shards" },
    CsvColumn { header: "Requests", metric: "asrkf_requests_completed_total" },
    CsvColumn { header: "Tokens", metric: "asrkf_tokens_generated_total" },
    CsvColumn { header: "Wall (s)", metric: "" },
    CsvColumn { header: "tok/s", metric: "" },
    CsvColumn { header: "mean e2e (ms)", metric: "asrkf_e2e_us" },
    CsvColumn { header: "hot KB (peak/req)", metric: "asrkf_tier_peak_bytes" },
    CsvColumn { header: "cold KB (peak/req)", metric: "asrkf_tier_peak_bytes" },
    CsvColumn { header: "staged hit", metric: "asrkf_staged_total" },
    CsvColumn { header: "restore hot (us)", metric: "asrkf_restore_us" },
    CsvColumn { header: "restore cold (us)", metric: "asrkf_restore_us" },
    CsvColumn { header: "restored rows", metric: "asrkf_restore_batch_rows_total" },
    CsvColumn { header: "restore spans", metric: "asrkf_restore_batch_spans_total" },
    CsvColumn { header: "restore par", metric: "asrkf_restore_parallelism" },
    CsvColumn { header: "recovered rows", metric: "asrkf_recovered_rows_total" },
    CsvColumn { header: "restore wait (us)", metric: "asrkf_restore_wait_us" },
    CsvColumn { header: "late arrivals", metric: "asrkf_late_arrivals_total" },
    CsvColumn { header: "bytes/row (hot)", metric: "asrkf_tier_row_bytes_total" },
    CsvColumn { header: "bytes/row (cold)", metric: "asrkf_tier_row_bytes_total" },
    CsvColumn { header: "bytes/row (spill)", metric: "asrkf_tier_row_bytes_total" },
    CsvColumn { header: "plan mean (us)", metric: "asrkf_plan_us" },
    CsvColumn { header: "plan p99 (us)", metric: "asrkf_plan_us" },
    CsvColumn { header: "rows lost", metric: "asrkf_rows_lost_total" },
    CsvColumn { header: "shard rebuilds", metric: "asrkf_shard_rebuilds_total" },
];

/// Header strings of [`SERVING_CSV_COLUMNS`], in order.
pub fn serving_csv_headers() -> Vec<&'static str> {
    SERVING_CSV_COLUMNS.iter().map(|c| c.header).collect()
}

/// Column schema of `artifacts/load_gen.csv` (the closed-loop QoS
/// load-generator bench, `benches/load_gen.rs`). Same contract as
/// [`SERVING_CSV_COLUMNS`]: headers built from this list, referenced
/// metrics checked against [`CATALOG`] in `tests/telemetry.rs`.
pub const LOAD_GEN_CSV_COLUMNS: &[CsvColumn] = &[
    CsvColumn { header: "Mode", metric: "" },
    CsvColumn { header: "Arrivals", metric: "" },
    CsvColumn { header: "Completed", metric: "asrkf_requests_completed_total" },
    CsvColumn { header: "goodput (tok/s)", metric: "asrkf_tokens_generated_total" },
    CsvColumn { header: "reject rate", metric: "asrkf_admission_total" },
    CsvColumn { header: "shed rate", metric: "asrkf_admission_total" },
    CsvColumn { header: "p99 interactive (ms)", metric: "asrkf_e2e_us" },
    CsvColumn { header: "p99 standard (ms)", metric: "asrkf_e2e_us" },
    CsvColumn { header: "p99 batch (ms)", metric: "asrkf_e2e_us" },
    CsvColumn { header: "queue p99 interactive (ms)", metric: "asrkf_queue_wait_us" },
    CsvColumn { header: "queue p99 batch (ms)", metric: "asrkf_queue_wait_us" },
    CsvColumn { header: "mean occupancy", metric: "asrkf_batch_occupancy" },
];

/// Header strings of [`LOAD_GEN_CSV_COLUMNS`], in order.
pub fn load_gen_csv_headers() -> Vec<&'static str> {
    LOAD_GEN_CSV_COLUMNS.iter().map(|c| c.header).collect()
}

// ---------------------------------------------------------------------------
// Builder

type LabelKey = Vec<(String, String)>;

fn label_key(labels: &[(&str, &str)]) -> LabelKey {
    let mut v: LabelKey =
        labels.iter().map(|(k, val)| (k.to_string(), val.to_string())).collect();
    v.sort();
    v
}

#[derive(Debug, Clone)]
enum Agg {
    Counter(u64),
    Gauge(f64),
    Time(Histogram),
    Count(CountHistogram),
}

/// Accumulates published series; `finish()` freezes it into a
/// [`Snapshot`]. Producers with pre-existing local histograms merge
/// them in wholesale (`time_merge`/`count_merge`), so per-shard and
/// per-session state aggregates only at snapshot time.
#[derive(Debug, Clone, Default)]
pub struct SnapshotBuilder {
    series: BTreeMap<&'static str, BTreeMap<LabelKey, Agg>>,
}

impl SnapshotBuilder {
    fn slot(
        &mut self,
        name: &'static str,
        labels: &[(&str, &str)],
        make: fn() -> Agg,
    ) -> &mut Agg {
        self.series
            .entry(name)
            .or_default()
            .entry(label_key(labels))
            .or_insert_with(make)
    }

    pub fn counter_add(&mut self, name: &'static str, labels: &[(&str, &str)], v: u64) {
        match self.slot(name, labels, || Agg::Counter(0)) {
            Agg::Counter(c) => *c += v,
            _ => log::error!("metric {name} published as counter but registered otherwise"),
        }
    }

    /// Overwrite a gauge (point-in-time value).
    pub fn gauge_set(&mut self, name: &'static str, labels: &[(&str, &str)], v: f64) {
        match self.slot(name, labels, || Agg::Gauge(0.0)) {
            Agg::Gauge(g) => *g = v,
            _ => log::error!("metric {name} published as gauge but registered otherwise"),
        }
    }

    /// Add onto a gauge (summing one logical gauge over publishers).
    pub fn gauge_add(&mut self, name: &'static str, labels: &[(&str, &str)], v: f64) {
        match self.slot(name, labels, || Agg::Gauge(0.0)) {
            Agg::Gauge(g) => *g += v,
            _ => log::error!("metric {name} published as gauge but registered otherwise"),
        }
    }

    pub fn time_record(&mut self, name: &'static str, labels: &[(&str, &str)], d: Duration) {
        match self.slot(name, labels, || Agg::Time(Histogram::default())) {
            Agg::Time(h) => h.record(d),
            _ => log::error!("metric {name} published as time-histogram but registered otherwise"),
        }
    }

    pub fn time_merge(&mut self, name: &'static str, labels: &[(&str, &str)], other: &Histogram) {
        if other.count() == 0 {
            return;
        }
        match self.slot(name, labels, || Agg::Time(Histogram::default())) {
            Agg::Time(h) => h.merge(other),
            _ => log::error!("metric {name} published as time-histogram but registered otherwise"),
        }
    }

    pub fn count_record(&mut self, name: &'static str, labels: &[(&str, &str)], v: u64) {
        match self.slot(name, labels, || Agg::Count(CountHistogram::default())) {
            Agg::Count(h) => h.record(v),
            _ => log::error!("metric {name} published as count-histogram but registered otherwise"),
        }
    }

    pub fn count_merge(
        &mut self,
        name: &'static str,
        labels: &[(&str, &str)],
        other: &CountHistogram,
    ) {
        if other.count() == 0 {
            return;
        }
        match self.slot(name, labels, || Agg::Count(CountHistogram::default())) {
            Agg::Count(h) => h.merge(other),
            _ => log::error!("metric {name} published as count-histogram but registered otherwise"),
        }
    }

    /// Freeze into an immutable snapshot (histograms summarized).
    pub fn finish(self) -> Snapshot {
        let series = self
            .series
            .into_iter()
            .map(|(name, by_label)| {
                let by_label = by_label
                    .into_iter()
                    .map(|(k, agg)| {
                        let sample = match agg {
                            Agg::Counter(v) => Sample::Counter(v),
                            Agg::Gauge(v) => Sample::Gauge(v),
                            Agg::Time(h) => Sample::Hist(HistStats::from_time(&h)),
                            Agg::Count(h) => Sample::Hist(HistStats::from_count(&h)),
                        };
                        (k, sample)
                    })
                    .collect();
                (name, by_label)
            })
            .collect();
        Snapshot { series }
    }
}

// ---------------------------------------------------------------------------
// Snapshot

/// Frozen histogram summary (values in the metric's declared unit:
/// microseconds for time histograms, raw counts otherwise).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistStats {
    pub count: u64,
    pub sum: f64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistStats {
    fn from_time(h: &Histogram) -> Self {
        let count = h.count();
        let sum = h.sum_us() as f64;
        HistStats {
            count,
            sum,
            mean: if count == 0 { 0.0 } else { sum / count as f64 },
            p50: h.quantile(0.5).as_micros() as f64,
            p90: h.quantile(0.9).as_micros() as f64,
            p99: h.quantile(0.99).as_micros() as f64,
            max: h.max().as_micros() as f64,
        }
    }

    fn from_count(h: &CountHistogram) -> Self {
        let count = h.count();
        let sum = h.sum() as f64;
        HistStats {
            count,
            sum,
            mean: h.mean(),
            p50: h.quantile(0.5) as f64,
            p90: h.quantile(0.9) as f64,
            p99: h.quantile(0.99) as f64,
            max: h.max() as f64,
        }
    }
}

/// One frozen sample of a series.
#[derive(Debug, Clone, PartialEq)]
pub enum Sample {
    Counter(u64),
    Gauge(f64),
    Hist(HistStats),
}

/// Immutable point-in-time view of every published series, with the
/// query helpers `OffloadSummary::from_snapshot` and the tests use.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    series: BTreeMap<&'static str, BTreeMap<LabelKey, Sample>>,
}

fn labels_match(labels: &[(String, String)], filter: &[(&str, &str)]) -> bool {
    filter
        .iter()
        .all(|(fk, fv)| labels.iter().any(|(k, v)| k == fk && v == fv))
}

impl Snapshot {
    /// Exact-label counter lookup (0 when absent).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        match self.series.get(name).and_then(|s| s.get(&label_key(labels))) {
            Some(Sample::Counter(v)) => *v,
            _ => 0,
        }
    }

    /// Sum of every counter series whose labels contain all `filter`
    /// pairs (use `&[]` to sum over all label sets, e.g. all shards).
    pub fn counter_sum(&self, name: &str, filter: &[(&str, &str)]) -> u64 {
        self.filtered(name, filter)
            .filter_map(|s| match s {
                Sample::Counter(v) => Some(*v),
                _ => None,
            })
            .sum()
    }

    /// Exact-label gauge lookup (0.0 when absent).
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        match self.series.get(name).and_then(|s| s.get(&label_key(labels))) {
            Some(Sample::Gauge(v)) => *v,
            _ => 0.0,
        }
    }

    pub fn gauge_sum(&self, name: &str, filter: &[(&str, &str)]) -> f64 {
        self.filtered(name, filter)
            .filter_map(|s| match s {
                Sample::Gauge(v) => Some(*v),
                _ => None,
            })
            .sum()
    }

    pub fn gauge_min(&self, name: &str, filter: &[(&str, &str)]) -> Option<f64> {
        self.filtered(name, filter)
            .filter_map(|s| match s {
                Sample::Gauge(v) => Some(*v),
                _ => None,
            })
            .reduce(f64::min)
    }

    pub fn gauge_max(&self, name: &str, filter: &[(&str, &str)]) -> Option<f64> {
        self.filtered(name, filter)
            .filter_map(|s| match s {
                Sample::Gauge(v) => Some(*v),
                _ => None,
            })
            .reduce(f64::max)
    }

    /// Exact-label histogram lookup.
    pub fn hist(&self, name: &str, labels: &[(&str, &str)]) -> Option<&HistStats> {
        match self.series.get(name).and_then(|s| s.get(&label_key(labels))) {
            Some(Sample::Hist(h)) => Some(h),
            _ => None,
        }
    }

    fn filtered<'a>(
        &'a self,
        name: &str,
        filter: &'a [(&'a str, &'a str)],
    ) -> impl Iterator<Item = &'a Sample> + 'a {
        self.series
            .get(name)
            .into_iter()
            .flat_map(|s| s.iter())
            .filter(move |(labels, _)| labels_match(labels, filter))
            .map(|(_, s)| s)
    }

    /// Every gauge series under `name` as `(label set, value)` pairs —
    /// lets callers enumerate dynamic label values (e.g. bench section
    /// names) without knowing them in advance.
    pub fn gauge_series(&self, name: &str) -> Vec<(Vec<(String, String)>, f64)> {
        self.series
            .get(name)
            .into_iter()
            .flat_map(|s| s.iter())
            .filter_map(|(labels, s)| match s {
                Sample::Gauge(v) => Some((labels.clone(), *v)),
                _ => None,
            })
            .collect()
    }

    /// All metric names present in the snapshot.
    pub fn names(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.series.keys().copied()
    }

    /// Total number of (name, label-set) series.
    pub fn series_count(&self) -> usize {
        self.series.values().map(|s| s.len()).sum()
    }

    /// JSON shape: `{name: [{"labels": {...}, ...sample fields}]}`.
    pub fn to_json(&self) -> Json {
        let mut top = BTreeMap::new();
        for (name, by_label) in &self.series {
            let mut arr = Vec::new();
            for (labels, sample) in by_label {
                let label_obj = Json::Obj(
                    labels
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                );
                let mut fields = vec![("labels", label_obj)];
                match sample {
                    Sample::Counter(v) => fields.push(("value", Json::num(*v as f64))),
                    Sample::Gauge(v) => fields.push(("value", Json::num(*v))),
                    Sample::Hist(h) => {
                        fields.push(("count", Json::num(h.count as f64)));
                        fields.push(("sum", Json::num(h.sum)));
                        fields.push(("mean", Json::num(h.mean)));
                        fields.push(("p50", Json::num(h.p50)));
                        fields.push(("p90", Json::num(h.p90)));
                        fields.push(("p99", Json::num(h.p99)));
                        fields.push(("max", Json::num(h.max)));
                    }
                }
                arr.push(Json::obj(fields));
            }
            top.insert(name.to_string(), Json::Arr(arr));
        }
        Json::Obj(top)
    }

    /// Prometheus text exposition (histograms as summary-type samples
    /// with `quantile` labels plus `_sum`/`_count`).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, by_label) in &self.series {
            match spec_for(name) {
                Some(spec) => {
                    let _ = writeln!(out, "# HELP {} {}", name, spec.help);
                    let _ = writeln!(out, "# TYPE {} {}", name, spec.kind.prometheus_type());
                }
                None => {
                    let _ = writeln!(out, "# TYPE {name} untyped");
                }
            }
            for (labels, sample) in by_label {
                match sample {
                    Sample::Counter(v) => prom_line(&mut out, name, labels, None, *v as f64),
                    Sample::Gauge(v) => prom_line(&mut out, name, labels, None, *v),
                    Sample::Hist(h) => {
                        for (q, v) in
                            [("0.5", h.p50), ("0.9", h.p90), ("0.99", h.p99)]
                        {
                            prom_line(&mut out, name, labels, Some(("quantile", q)), v);
                        }
                        prom_line(&mut out, &format!("{name}_sum"), labels, None, h.sum);
                        prom_line(&mut out, &format!("{name}_count"), labels, None, h.count as f64);
                    }
                }
            }
        }
        out
    }

    /// One-line operator summary for `--metrics-interval` logging.
    pub fn summary_line(&self) -> String {
        let hot = self.gauge_sum("asrkf_tier_bytes", &[("tier", "hot")]);
        let cold = self.gauge_sum("asrkf_tier_bytes", &[("tier", "cold")]);
        let spill = self.gauge_sum("asrkf_tier_bytes", &[("tier", "spill")]);
        let step = self.hist("asrkf_step_us", &[]);
        format!(
            "stashed={} restored={} dropped={} staged hit/miss={}/{} tiers KB hot/cold/spill={:.0}/{:.0}/{:.0} requests ok/rej={}/{} tokens={} step p50/p99 us={:.0}/{:.0}",
            self.counter_sum("asrkf_stash_total", &[]),
            self.counter_sum("asrkf_restore_total", &[]),
            self.counter_sum("asrkf_drop_total", &[]),
            self.counter_sum("asrkf_staged_total", &[("result", "hit")]),
            self.counter_sum("asrkf_staged_total", &[("result", "miss")]),
            hot / 1024.0,
            cold / 1024.0,
            spill / 1024.0,
            self.counter_sum("asrkf_requests_completed_total", &[]),
            self.counter_sum("asrkf_requests_rejected_total", &[]),
            self.counter_sum("asrkf_tokens_generated_total", &[]),
            step.map(|h| h.p50).unwrap_or(0.0),
            step.map(|h| h.p99).unwrap_or(0.0),
        )
    }
}

fn prom_line(
    out: &mut String,
    name: &str,
    labels: &[(String, String)],
    extra: Option<(&str, &str)>,
    value: f64,
) {
    use std::fmt::Write as _;
    out.push_str(name);
    let n_labels = labels.len() + usize::from(extra.is_some());
    if n_labels > 0 {
        out.push('{');
        let mut first = true;
        for (k, v) in labels
            .iter()
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .chain(extra)
        {
            if !first {
                out.push(',');
            }
            first = false;
            let _ = write!(out, "{k}=\"");
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    if value.fract() == 0.0 && value.abs() < 1e15 {
        let _ = writeln!(out, " {}", value as i64);
    } else {
        let _ = writeln!(out, " {value}");
    }
}

/// Validate a Prometheus text exposition: every non-comment line must
/// be `name[{k="v",...}] value`. Returns the number of samples parsed.
/// Used by the CI round-trip smoke test; intentionally strict about
/// name charset, brace/quote structure, and the value being a float.
pub fn parse_exposition(text: &str) -> Result<usize, String> {
    fn valid_name(s: &str) -> bool {
        !s.is_empty()
            && s.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':').unwrap_or(false)
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    let mut samples = 0usize;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let lineno = i + 1;
        let (name_part, rest) = match line.find(|c: char| c == '{' || c == ' ') {
            Some(idx) => (&line[..idx], &line[idx..]),
            None => return Err(format!("line {lineno}: no value separator")),
        };
        if !valid_name(name_part) {
            return Err(format!("line {lineno}: bad metric name '{name_part}'"));
        }
        let value_part = if let Some(body) = rest.strip_prefix('{') {
            // scan for the closing brace outside quotes
            let mut in_quotes = false;
            let mut escaped = false;
            let mut close = None;
            for (j, c) in body.char_indices() {
                if escaped {
                    escaped = false;
                    continue;
                }
                match c {
                    '\\' if in_quotes => escaped = true,
                    '"' => in_quotes = !in_quotes,
                    '}' if !in_quotes => {
                        close = Some(j);
                        break;
                    }
                    _ => {}
                }
            }
            let close = close.ok_or_else(|| format!("line {lineno}: unterminated label set"))?;
            let labels = &body[..close];
            if !labels.is_empty() {
                for pair in split_label_pairs(labels) {
                    let (k, v) = pair
                        .split_once('=')
                        .ok_or_else(|| format!("line {lineno}: label pair '{pair}' missing '='"))?;
                    if !valid_name(k) {
                        return Err(format!("line {lineno}: bad label name '{k}'"));
                    }
                    if !(v.len() >= 2 && v.starts_with('"') && v.ends_with('"')) {
                        return Err(format!("line {lineno}: label value {v} not quoted"));
                    }
                }
            }
            &body[close + 1..]
        } else {
            rest
        };
        let value = value_part.trim();
        let ok = matches!(value, "NaN" | "+Inf" | "-Inf") || value.parse::<f64>().is_ok();
        if !ok {
            return Err(format!("line {lineno}: bad sample value '{value}'"));
        }
        samples += 1;
    }
    Ok(samples)
}

/// Split `k="v",k2="v2"` on commas outside quotes.
fn split_label_pairs(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_quotes = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_quotes => escaped = true,
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(s[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(s[start..].trim());
    }
    out
}

// ---------------------------------------------------------------------------
// Registry

/// Thread-safe accumulating registry. `Registry::global()` is the
/// process-wide instance the server's `stats` request and the
/// `--metrics-interval` logger snapshot; sessions publish into it when
/// they retire. Per-store snapshots (`TieredStore::snapshot`) use a
/// private builder instead, so a store's view is never polluted by
/// other sessions.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<SnapshotBuilder>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn global() -> &'static Registry {
        GLOBAL.get_or_init(Registry::new)
    }

    /// Publish a batch of series under one lock acquisition.
    pub fn publish<F: FnOnce(&mut SnapshotBuilder)>(&self, f: F) {
        let mut b = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut b);
    }

    pub fn counter_add(&self, name: &'static str, labels: &[(&str, &str)], v: u64) {
        self.publish(|b| b.counter_add(name, labels, v));
    }

    pub fn gauge_set(&self, name: &'static str, labels: &[(&str, &str)], v: f64) {
        self.publish(|b| b.gauge_set(name, labels, v));
    }

    pub fn time_record(&self, name: &'static str, labels: &[(&str, &str)], d: Duration) {
        self.publish(|b| b.time_record(name, labels, d));
    }

    pub fn count_record(&self, name: &'static str, labels: &[(&str, &str)], v: u64) {
        self.publish(|b| b.count_record(name, labels, v));
    }

    pub fn snapshot(&self) -> Snapshot {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).clone().finish()
    }
}

/// Spawn a detached thread that logs the global registry's summary
/// line every `secs` seconds (no-op for `secs == 0`). Driven by the
/// `--metrics-interval` flag on `generate` and `serve`.
pub fn start_interval_logger(secs: u64) {
    if secs == 0 {
        return;
    }
    std::thread::Builder::new()
        .name("asrkf-metrics".into())
        .spawn(move || loop {
            std::thread::sleep(Duration::from_secs(secs));
            log::info!("{}", Registry::global().snapshot().summary_line());
        })
        .expect("spawn metrics interval logger");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Snapshot {
        let mut b = SnapshotBuilder::default();
        b.counter_add("asrkf_stash_total", &[("shard", "0")], 5);
        b.counter_add("asrkf_stash_total", &[("shard", "1")], 7);
        b.counter_add("asrkf_staged_total", &[("result", "hit"), ("shard", "0")], 3);
        b.counter_add("asrkf_staged_total", &[("result", "miss"), ("shard", "0")], 2);
        b.gauge_set("asrkf_tier_bytes", &[("tier", "hot"), ("shard", "0")], 1024.0);
        b.gauge_set("asrkf_tier_bytes", &[("tier", "hot"), ("shard", "1")], 2048.0);
        b.time_record("asrkf_restore_us", &[("tier", "hot")], Duration::from_micros(100));
        b.time_record("asrkf_restore_us", &[("tier", "hot")], Duration::from_micros(300));
        b.count_record("asrkf_sched_depth", &[], 4);
        b.finish()
    }

    #[test]
    fn counters_accumulate_and_filter() {
        let s = sample_snapshot();
        assert_eq!(s.counter("asrkf_stash_total", &[("shard", "0")]), 5);
        assert_eq!(s.counter_sum("asrkf_stash_total", &[]), 12);
        assert_eq!(s.counter_sum("asrkf_staged_total", &[("result", "hit")]), 3);
        assert_eq!(s.counter("asrkf_stash_total", &[("shard", "9")]), 0);
    }

    #[test]
    fn label_order_is_normalized() {
        let mut b = SnapshotBuilder::default();
        b.counter_add("asrkf_staged_total", &[("shard", "0"), ("result", "hit")], 1);
        b.counter_add("asrkf_staged_total", &[("result", "hit"), ("shard", "0")], 1);
        let s = b.finish();
        assert_eq!(s.counter("asrkf_staged_total", &[("result", "hit"), ("shard", "0")]), 2);
        assert_eq!(s.series_count(), 1);
    }

    #[test]
    fn gauges_sum_min_max() {
        let s = sample_snapshot();
        assert_eq!(s.gauge_sum("asrkf_tier_bytes", &[("tier", "hot")]), 3072.0);
        assert_eq!(s.gauge_min("asrkf_tier_bytes", &[]), Some(1024.0));
        assert_eq!(s.gauge_max("asrkf_tier_bytes", &[]), Some(2048.0));
        assert_eq!(s.gauge_min("asrkf_absent", &[]), None);
    }

    #[test]
    fn hist_summary_fields() {
        let s = sample_snapshot();
        let h = s.hist("asrkf_restore_us", &[("tier", "hot")]).unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.sum, 400.0);
        assert_eq!(h.mean, 200.0);
        assert!(h.p50 <= h.p99 && h.p99 <= h.max);
        let d = s.hist("asrkf_sched_depth", &[]).unwrap();
        assert_eq!(d.count, 1);
        assert_eq!(d.max, 4.0);
    }

    #[test]
    fn kind_mismatch_is_logged_not_merged() {
        let mut b = SnapshotBuilder::default();
        b.counter_add("asrkf_stash_total", &[], 1);
        b.gauge_set("asrkf_stash_total", &[], 99.0);
        let s = b.finish();
        assert_eq!(s.counter("asrkf_stash_total", &[]), 1, "gauge write must not clobber");
    }

    #[test]
    fn json_shape() {
        let s = sample_snapshot();
        let j = s.to_json();
        let arr = j.get("asrkf_stash_total").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("labels").get("shard").as_str(), Some("0"));
        assert_eq!(arr[0].get("value").as_usize(), Some(5));
        let h = &j.get("asrkf_restore_us").as_arr().unwrap()[0];
        assert_eq!(h.get("count").as_usize(), Some(2));
        // round-trips through the crate JSON writer/parser
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("asrkf_sched_depth").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn prometheus_exposition_parses() {
        let s = sample_snapshot();
        let text = s.to_prometheus();
        assert!(text.contains("# TYPE asrkf_stash_total counter"));
        assert!(text.contains("asrkf_stash_total{shard=\"0\"} 5"));
        assert!(text.contains("asrkf_restore_us{tier=\"hot\",quantile=\"0.5\"}"));
        assert!(text.contains("asrkf_restore_us_count{tier=\"hot\"} 2"));
        let n = parse_exposition(&text).unwrap();
        assert!(n >= 10, "expected at least 10 samples, got {n}");
    }

    #[test]
    fn exposition_validator_rejects_garbage() {
        assert!(parse_exposition("1bad_name 3\n").is_err());
        assert!(parse_exposition("name{unterminated=\"x\" 3\n").is_err());
        assert!(parse_exposition("name{k=unquoted} 3\n").is_err());
        assert!(parse_exposition("name notanumber\n").is_err());
        assert_eq!(parse_exposition("# just a comment\n\n").unwrap(), 0);
        assert_eq!(parse_exposition("ok{k=\"a,b\",j=\"c\\\"d\"} 1.5\nplain 2\n").unwrap(), 2);
    }

    #[test]
    fn catalog_names_unique_and_csv_schema_resolves() {
        let mut seen = std::collections::BTreeSet::new();
        for spec in CATALOG {
            assert!(seen.insert(spec.name), "duplicate metric {}", spec.name);
            assert!(!spec.help.is_empty());
        }
        for col in SERVING_CSV_COLUMNS.iter().chain(LOAD_GEN_CSV_COLUMNS) {
            if !col.metric.is_empty() {
                assert!(
                    spec_for(col.metric).is_some(),
                    "CSV column '{}' references unregistered metric '{}'",
                    col.header,
                    col.metric
                );
            }
        }
        assert_eq!(serving_csv_headers().len(), SERVING_CSV_COLUMNS.len());
        assert_eq!(load_gen_csv_headers().len(), LOAD_GEN_CSV_COLUMNS.len());
    }

    #[test]
    fn summary_line_mentions_totals() {
        let line = sample_snapshot().summary_line();
        assert!(line.contains("stashed=12"), "{line}");
        assert!(line.contains("staged hit/miss=3/2"), "{line}");
    }
}
