//! Entropy-guided recovery (paper §3.6 — listed as future work there,
//! implemented here as a first-class feature): an entropy monitor and
//! the SR -> WR -> FR -> RR escalation ladder.

pub mod entropy;
pub mod ladder;

pub use entropy::{EntropyMonitor, Signal};
pub use ladder::{Action, RecoveryLadder};
