//! The four-level recovery escalation ladder (paper §3.6):
//! SR -> WR -> FR -> RR, with cooldowns between interventions and
//! de-escalation after a quiet period.

use crate::config::RecoveryConfig;
use crate::recovery::entropy::Signal;

/// Intervention the engine must apply this step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    None,
    /// SR: unfreeze tokens with remaining duration > 1.
    SoftReset,
    /// WR: unfreeze tokens frozen in the last N steps.
    WindowReset { horizon: u64 },
    /// FR: unfreeze everything, clear all counters.
    FullReset,
    /// RR: FR + rewind and regenerate the last k tokens.
    Rewalk { depth: usize },
}

#[derive(Debug)]
pub struct RecoveryLadder {
    cfg: RecoveryConfig,
    /// current escalation level (0 = calm, 1..=4 applied levels)
    level: u8,
    /// steps remaining before another intervention may fire
    cooldown: usize,
    /// quiet steps observed since the last intervention
    quiet: usize,
    pub interventions: Vec<(u64, Action)>,
}

impl RecoveryLadder {
    pub fn new(cfg: RecoveryConfig) -> Self {
        RecoveryLadder { cfg, level: 0, cooldown: 0, quiet: 0, interventions: Vec::new() }
    }

    /// Feed the monitor's signal for `step`; returns the action to apply.
    pub fn step(&mut self, step: u64, signal: Signal) -> Action {
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
        match signal {
            Signal::Ok => {
                self.quiet += 1;
                // de-escalate after a sustained quiet period
                if self.level > 0 && self.quiet >= self.cfg.escalation_patience * 4 {
                    self.level = 0;
                }
                Action::None
            }
            Signal::Spike | Signal::ConfidenceDrop => {
                self.quiet = 0;
                if self.cooldown > 0 {
                    return Action::None;
                }
                // escalate: repeated triggers walk up the ladder
                self.level = (self.level + 1).min(4);
                self.cooldown = self.cfg.cooldown;
                let action = match self.level {
                    1 => Action::SoftReset,
                    2 => Action::WindowReset { horizon: self.cfg.wr_horizon as u64 },
                    3 => Action::FullReset,
                    _ => Action::Rewalk { depth: self.cfg.rr_depth },
                };
                self.interventions.push((step, action));
                action
            }
        }
    }

    pub fn level(&self) -> u8 {
        self.level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> RecoveryLadder {
        RecoveryLadder::new(RecoveryConfig {
            cooldown: 3,
            escalation_patience: 2,
            wr_horizon: 16,
            rr_depth: 4,
            ..Default::default()
        })
    }

    #[test]
    fn escalates_through_all_levels() {
        let mut l = ladder();
        let mut actions = Vec::new();
        let mut step = 0u64;
        for _ in 0..4 {
            // trigger, then wait out the cooldown with more trouble
            loop {
                step += 1;
                let a = l.step(step, Signal::Spike);
                if a != Action::None {
                    actions.push(a);
                    break;
                }
            }
        }
        assert_eq!(
            actions,
            vec![
                Action::SoftReset,
                Action::WindowReset { horizon: 16 },
                Action::FullReset,
                Action::Rewalk { depth: 4 },
            ]
        );
    }

    #[test]
    fn cooldown_suppresses_back_to_back_interventions() {
        let mut l = ladder();
        assert_ne!(l.step(1, Signal::Spike), Action::None);
        assert_eq!(l.step(2, Signal::Spike), Action::None);
        assert_eq!(l.step(3, Signal::Spike), Action::None);
    }

    #[test]
    fn deescalates_after_quiet_period() {
        let mut l = ladder();
        l.step(1, Signal::Spike);
        assert_eq!(l.level(), 1);
        for s in 2..12 {
            l.step(s, Signal::Ok);
        }
        assert_eq!(l.level(), 0);
        // next trouble starts from SR again
        let mut a = Action::None;
        let mut s = 12;
        while a == Action::None {
            s += 1;
            a = l.step(s, Signal::Spike);
        }
        assert_eq!(a, Action::SoftReset);
    }

    #[test]
    fn rewalk_is_terminal_level() {
        let mut l = ladder();
        let mut step = 0;
        for _ in 0..10 {
            loop {
                step += 1;
                if l.step(step, Signal::Spike) != Action::None {
                    break;
                }
            }
        }
        assert!(matches!(l.interventions.last().unwrap().1, Action::Rewalk { .. }));
        assert_eq!(l.level(), 4);
    }
}
