//! Entropy monitoring (paper §3.6): detects "entropy spikes or
//! confidence drops" against an exponentially-weighted baseline.
//!
//! The monitor keeps an EMA of the per-step logits entropy and its
//! variance; a step triggers when
//!     H_t > ema + lambda * std      (spike)
//! or  top1_t < 0.5 * top1_ema      (confidence collapse)
//! after a short warmup so the baseline is meaningful.

use crate::config::RecoveryConfig;

#[derive(Debug, Clone)]
pub struct EntropyMonitor {
    cfg: RecoveryConfig,
    ema: f32,
    var: f32,
    top1_ema: f32,
    steps: u64,
    warmup: u64,
    /// how close the last observation came to a trigger (see
    /// [`EntropyMonitor::pressure`])
    last_pressure: f32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    Ok,
    Spike,
    ConfidenceDrop,
}

impl EntropyMonitor {
    pub fn new(cfg: RecoveryConfig) -> Self {
        EntropyMonitor {
            cfg,
            ema: 0.0,
            var: 0.0,
            top1_ema: 0.0,
            steps: 0,
            warmup: 8,
            last_pressure: 0.0,
        }
    }

    /// Feed one step's entropy (nats) and top-1 probability.
    pub fn observe(&mut self, entropy: f32, top1: f32) -> Signal {
        self.steps += 1;
        if self.steps <= self.warmup {
            // seed the baseline
            if self.steps == 1 {
                self.ema = entropy;
                self.top1_ema = top1;
            } else {
                self.update(entropy, top1);
            }
            self.last_pressure = 0.0;
            return Signal::Ok;
        }

        let std = self.var.sqrt().max(0.05); // floor avoids zero-variance hair triggers
        // pressure: fraction of the trigger threshold reached this step
        // (1.0 == a trigger fires). Consumed by the offload store's
        // prefetch-ahead staging, so likely recovery restores are hot.
        let spike_frac = (entropy - self.ema) / (self.cfg.lambda * std).max(1e-6);
        let conf_frac = if self.top1_ema > 0.0 {
            (1.0 - top1 / self.top1_ema) / 0.5
        } else {
            0.0
        };
        self.last_pressure = spike_frac.max(conf_frac).clamp(0.0, 2.0);

        let signal = if entropy > self.ema + self.cfg.lambda * std {
            Signal::Spike
        } else if top1 < 0.5 * self.top1_ema {
            Signal::ConfidenceDrop
        } else {
            Signal::Ok
        };
        self.update(entropy, top1);
        signal
    }

    /// How close the last step trended toward a recovery trigger, as a
    /// fraction of the trigger threshold: 0.0 = at/below baseline,
    /// 1.0 = a trigger fired, clamped to 2.0. Stays 0 during warmup.
    pub fn pressure(&self) -> f32 {
        self.last_pressure
    }

    fn update(&mut self, entropy: f32, top1: f32) {
        let a = self.cfg.ema_decay;
        let delta = entropy - self.ema;
        self.ema = a * self.ema + (1.0 - a) * entropy;
        self.var = a * self.var + (1.0 - a) * delta * delta;
        self.top1_ema = a * self.top1_ema + (1.0 - a) * top1;
    }

    /// Reset after an intervention so the new regime sets a fresh baseline.
    pub fn reset(&mut self) {
        self.steps = 0;
        self.ema = 0.0;
        self.var = 0.0;
        self.top1_ema = 0.0;
        self.last_pressure = 0.0;
    }

    pub fn baseline(&self) -> (f32, f32) {
        (self.ema, self.var.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mon() -> EntropyMonitor {
        EntropyMonitor::new(RecoveryConfig { lambda: 3.0, ema_decay: 0.9, ..Default::default() })
    }

    #[test]
    fn stable_stream_never_triggers() {
        let mut m = mon();
        for i in 0..200 {
            let h = 2.0 + 0.01 * ((i % 7) as f32 - 3.0);
            assert_eq!(m.observe(h, 0.6), Signal::Ok, "step {i}");
        }
    }

    #[test]
    fn spike_detected_after_warmup() {
        let mut m = mon();
        for _ in 0..50 {
            m.observe(2.0, 0.6);
        }
        assert_eq!(m.observe(5.5, 0.6), Signal::Spike);
    }

    #[test]
    fn confidence_collapse_detected() {
        let mut m = mon();
        for _ in 0..50 {
            m.observe(2.0, 0.8);
        }
        assert_eq!(m.observe(2.0, 0.1), Signal::ConfidenceDrop);
    }

    #[test]
    fn no_trigger_during_warmup() {
        let mut m = mon();
        for i in 0..8 {
            assert_eq!(m.observe(if i == 5 { 50.0 } else { 2.0 }, 0.5), Signal::Ok);
        }
    }

    #[test]
    fn reset_requires_new_warmup() {
        let mut m = mon();
        for _ in 0..50 {
            m.observe(2.0, 0.6);
        }
        m.reset();
        assert_eq!(m.observe(9.0, 0.6), Signal::Ok); // warmup again
        assert_eq!(m.pressure(), 0.0);
    }

    #[test]
    fn pressure_tracks_proximity_to_trigger() {
        let mut m = mon();
        for _ in 0..50 {
            m.observe(2.0, 0.6);
        }
        m.observe(2.0, 0.6);
        let calm = m.pressure();
        assert!(calm < 0.5, "calm pressure {calm}");
        // halfway to the spike threshold (lambda=3, std floored at 0.05)
        m.observe(2.0 + 1.5 * 0.05, 0.6);
        let rising = m.pressure();
        assert!(rising > calm, "pressure must rise near the threshold");
        assert!(rising < 1.0, "not yet a trigger: {rising}");
        // full spike
        assert_eq!(m.observe(6.0, 0.6), Signal::Spike);
        assert!(m.pressure() >= 1.0);
    }
}
