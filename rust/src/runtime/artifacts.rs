//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{parse, Json};

/// Model dimensions (mirrors python `ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_len: usize,
    /// floats per token KV row bundle across layers (nl * 2 * H * D)
    pub kv_row_floats: usize,
}

/// One exported program variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramSpec {
    pub name: String,
    pub kind: ProgramKind,
    pub batch: usize,
    pub file: PathBuf,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramKind {
    /// prompt length bucket L
    Prefill { len: usize },
    /// KV capacity S + per-step transfer budget R
    Decode { kv_len: usize, r_budget: usize },
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelSpec,
    pub programs: BTreeMap<String, ProgramSpec>,
    pub block_k: usize,
    pub r_budget: usize,
    pub dir: PathBuf,
}

fn req_usize(v: &Json, key: &str, ctx: &str) -> Result<usize> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| Error::Manifest(format!("{ctx}: missing/invalid '{key}'")))
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            ))
        })?;
        Self::parse_str(&text, dir)
    }

    pub fn parse_str(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = parse(text).map_err(Error::Manifest)?;
        let m = root.get("model");
        let model = ModelSpec {
            vocab: req_usize(m, "vocab", "model")?,
            d_model: req_usize(m, "d_model", "model")?,
            n_layers: req_usize(m, "n_layers", "model")?,
            n_heads: req_usize(m, "n_heads", "model")?,
            d_head: req_usize(m, "d_head", "model")?,
            d_ff: req_usize(m, "d_ff", "model")?,
            max_len: req_usize(m, "max_len", "model")?,
            kv_row_floats: req_usize(m, "kv_row_floats", "model")?,
        };
        let expected_row = model.n_layers * 2 * model.n_heads * model.d_head;
        if model.kv_row_floats != expected_row {
            return Err(Error::Manifest(format!(
                "kv_row_floats {} inconsistent with dims ({} expected)",
                model.kv_row_floats, expected_row
            )));
        }

        let export = root.get("export");
        let block_k = req_usize(export, "block_k", "export")?;
        let r_budget = req_usize(export, "r_budget", "export")?;

        let progs = root
            .get("programs")
            .as_obj()
            .ok_or_else(|| Error::Manifest("missing 'programs'".into()))?;
        let mut programs = BTreeMap::new();
        for (name, p) in progs {
            let batch = req_usize(p, "batch", name)?;
            let file = dir.join(
                p.get("file")
                    .as_str()
                    .ok_or_else(|| Error::Manifest(format!("{name}: missing 'file'")))?,
            );
            let kind = match p.get("kind").as_str() {
                Some("prefill") => ProgramKind::Prefill { len: req_usize(p, "len", name)? },
                Some("decode") => ProgramKind::Decode {
                    kv_len: req_usize(p, "kv_len", name)?,
                    r_budget: req_usize(p, "r_budget", name)?,
                },
                other => {
                    return Err(Error::Manifest(format!("{name}: unknown kind {other:?}")))
                }
            };
            programs.insert(name.clone(), ProgramSpec { name: name.clone(), kind, batch, file });
        }
        if programs.is_empty() {
            return Err(Error::Manifest("no programs in manifest".into()));
        }
        Ok(Manifest { model, programs, block_k, r_budget, dir })
    }

    /// Smallest prefill bucket with len >= prompt_len (batch 1).
    pub fn prefill_bucket(&self, prompt_len: usize) -> Result<&ProgramSpec> {
        self.programs
            .values()
            .filter_map(|p| match p.kind {
                ProgramKind::Prefill { len } if len >= prompt_len && p.batch == 1 => {
                    Some((len, p))
                }
                _ => None,
            })
            .min_by_key(|(len, _)| *len)
            .map(|(_, p)| p)
            .ok_or_else(|| {
                Error::Manifest(format!("no prefill bucket fits prompt_len={prompt_len}"))
            })
    }

    /// Smallest decode bucket with batch >= `batch` and kv_len >= `need_len`.
    pub fn decode_bucket(&self, batch: usize, need_len: usize) -> Result<&ProgramSpec> {
        self.programs
            .values()
            .filter_map(|p| match p.kind {
                ProgramKind::Decode { kv_len, .. }
                    if kv_len >= need_len && p.batch >= batch =>
                {
                    Some(((p.batch, kv_len), p))
                }
                _ => None,
            })
            .min_by_key(|(key, _)| *key)
            .map(|(_, p)| p)
            .ok_or_else(|| {
                Error::Manifest(format!(
                    "no decode bucket fits batch={batch} need_len={need_len}"
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const SAMPLE: &str = r#"{
      "model": {"vocab":256,"d_model":128,"n_layers":4,"n_heads":4,"d_head":32,
                "d_ff":384,"max_len":2048,"rope_theta":10000.0,"kv_row_floats":1024},
      "export": {"prefill_buckets":[[1,128],[1,512]],
                 "decode_buckets":[[1,1024],[4,1024],[8,512]],
                 "r_budget":16,"block_k":64},
      "programs": {
        "prefill_b1_l128": {"kind":"prefill","batch":1,"len":128,"file":"prefill_b1_l128.hlo.txt"},
        "prefill_b1_l512": {"kind":"prefill","batch":1,"len":512,"file":"prefill_b1_l512.hlo.txt"},
        "decode_b1_s1024": {"kind":"decode","batch":1,"kv_len":1024,"r_budget":16,"file":"decode_b1_s1024.hlo.txt"},
        "decode_b4_s1024": {"kind":"decode","batch":4,"kv_len":1024,"r_budget":16,"file":"decode_b4_s1024.hlo.txt"},
        "decode_b8_s512": {"kind":"decode","batch":8,"kv_len":512,"r_budget":16,"file":"decode_b8_s512.hlo.txt"}
      }
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse_str(SAMPLE, PathBuf::from("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_model_and_programs() {
        let m = manifest();
        assert_eq!(m.model.n_layers, 4);
        assert_eq!(m.model.kv_row_floats, 1024);
        assert_eq!(m.programs.len(), 5);
        assert_eq!(m.block_k, 64);
    }

    #[test]
    fn prefill_bucket_selection() {
        let m = manifest();
        assert!(matches!(
            m.prefill_bucket(100).unwrap().kind,
            ProgramKind::Prefill { len: 128 }
        ));
        assert!(matches!(
            m.prefill_bucket(128).unwrap().kind,
            ProgramKind::Prefill { len: 128 }
        ));
        assert!(matches!(
            m.prefill_bucket(129).unwrap().kind,
            ProgramKind::Prefill { len: 512 }
        ));
        assert!(m.prefill_bucket(513).is_err());
    }

    #[test]
    fn decode_bucket_selection() {
        let m = manifest();
        let p = m.decode_bucket(1, 600).unwrap();
        assert_eq!(p.batch, 1);
        let p = m.decode_bucket(3, 600).unwrap();
        assert_eq!(p.batch, 4);
        let p = m.decode_bucket(8, 100).unwrap();
        assert_eq!(p.batch, 8);
        assert!(m.decode_bucket(8, 600).is_err());
    }

    #[test]
    fn rejects_inconsistent_row_floats() {
        let bad = SAMPLE.replace("\"kv_row_floats\":1024", "\"kv_row_floats\":7");
        assert!(Manifest::parse_str(&bad, PathBuf::from("/tmp")).is_err());
    }
}
