//! Literal <-> rust buffer conversion helpers.
//!
//! The decode hot loop builds several literals per step; these helpers
//! keep that path allocation-light and give one audited home to the
//! (safe-for-POD) byte reinterpretation.

use xla::{ElementType, Literal};

use crate::error::Result;

fn as_bytes<T>(data: &[T]) -> &[u8] {
    // SAFETY: f32/i32 are plain-old-data with no padding; the slice
    // lifetime is preserved and alignment of u8 is 1.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// Build an f32 literal of the given logical shape.
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        as_bytes(data),
    )?)
}

/// Build an i32 literal of the given logical shape.
pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        as_bytes(data),
    )?)
}

/// Copy a literal's f32 contents into a (correctly sized) slice.
pub fn copy_f32_into(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    lit.copy_raw_to(dst)?;
    Ok(())
}

/// Extract a literal's f32 contents as a fresh Vec.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        let lit = lit_f32(&[2, 3, 4], &data).unwrap();
        assert_eq!(lit.element_count(), 24);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data: Vec<i32> = vec![5, -1, 7, 2048];
        let lit = lit_i32(&[4], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn copy_into_preallocated() {
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let lit = lit_f32(&[8], &data).unwrap();
        let mut dst = vec![0.0f32; 8];
        copy_f32_into(&lit, &mut dst).unwrap();
        assert_eq!(dst, data);
    }
}
