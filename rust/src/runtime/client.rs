//! The runtime: a PJRT CPU client plus a lazily-compiled program
//! registry keyed by manifest program name.
//!
//! Adapted from the verified /opt/xla-example/load_hlo pattern:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile`. Compilation is cached per program; the engine owns
//! a `Runtime` on a single thread (PJRT CPU client is not `Sync`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use xla::PjRtClient;

use crate::error::{Error, Result};
use crate::runtime::artifacts::{Manifest, ProgramKind};
use crate::runtime::program::{DecodeProgram, PrefillProgram};

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    decode_cache: RefCell<BTreeMap<String, Rc<DecodeProgram>>>,
    prefill_cache: RefCell<BTreeMap<String, Rc<PrefillProgram>>>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        log::info!(
            "runtime: platform={} programs={}",
            client.platform_name(),
            manifest.programs.len()
        );
        Ok(Runtime {
            client,
            manifest,
            decode_cache: RefCell::new(BTreeMap::new()),
            prefill_cache: RefCell::new(BTreeMap::new()),
        })
    }

    fn compile(&self, file: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str()
                .ok_or_else(|| Error::Manifest(format!("non-utf8 path {file:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("compiled {} in {:.1}s", file.display(), t0.elapsed().as_secs_f64());
        Ok(exe)
    }

    /// Get (compiling on first use) the decode program with this name.
    pub fn decode_program(&self, name: &str) -> Result<Rc<DecodeProgram>> {
        if let Some(p) = self.decode_cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let spec = self
            .manifest
            .programs
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown program '{name}'")))?
            .clone();
        let ProgramKind::Decode { kv_len, r_budget } = spec.kind else {
            return Err(Error::Manifest(format!("'{name}' is not a decode program")));
        };
        let exe = self.compile(&spec.file)?;
        let prog = Rc::new(DecodeProgram::new(
            exe,
            spec.batch,
            kv_len,
            r_budget,
            self.manifest.model.clone(),
        ));
        self.decode_cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Get (compiling on first use) the prefill program with this name.
    pub fn prefill_program(&self, name: &str) -> Result<Rc<PrefillProgram>> {
        if let Some(p) = self.prefill_cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let spec = self
            .manifest
            .programs
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown program '{name}'")))?
            .clone();
        let ProgramKind::Prefill { len } = spec.kind else {
            return Err(Error::Manifest(format!("'{name}' is not a prefill program")));
        };
        let exe = self.compile(&spec.file)?;
        let prog = Rc::new(PrefillProgram::new(exe, spec.batch, len, self.manifest.model.clone()));
        self.prefill_cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Decode program for the smallest bucket fitting (batch, need_len).
    pub fn decode_for(&self, batch: usize, need_len: usize) -> Result<Rc<DecodeProgram>> {
        let name = self.manifest.decode_bucket(batch, need_len)?.name.clone();
        self.decode_program(&name)
    }

    /// Prefill program for the smallest bucket fitting prompt_len.
    pub fn prefill_for(&self, prompt_len: usize) -> Result<Rc<PrefillProgram>> {
        let name = self.manifest.prefill_bucket(prompt_len)?.name.clone();
        self.prefill_program(&name)
    }
}
