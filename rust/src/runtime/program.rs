//! Typed wrappers over compiled PJRT executables: `PrefillProgram` and
//! `DecodeProgram` match the signatures exported by `aot.py` (see
//! DESIGN.md §1 for the contract, python/compile/model.py for shapes).
//!
//! The decode program is a PURE function of the cache: rust owns every
//! state mutation (row writes, freeze/restore data movement) host-side;
//! the graph only computes. This keeps the step free of in-graph
//! full-cache copies (§Perf).

use std::time::{Duration, Instant};

use xla::{Literal, PjRtLoadedExecutable};

use crate::error::{Error, Result};
use crate::runtime::artifacts::ModelSpec;
use crate::runtime::literal::{lit_f32, lit_i32, to_vec_f32};

/// Per-call timing breakdown, aggregated by the engine for §Perf.
#[derive(Debug, Clone, Copy, Default)]
pub struct CallTiming {
    /// building input literals (host -> "device" transfer analog)
    pub upload: Duration,
    /// PJRT execute
    pub execute: Duration,
    /// fetching output literals ("device" -> host transfer analog)
    pub download: Duration,
}

impl CallTiming {
    pub fn total(&self) -> Duration {
        self.upload + self.execute + self.download
    }
}

// ---------------------------------------------------------------------------

/// Inputs to one decode step (slices borrowed from the session state).
pub struct DecodeInputs<'a> {
    pub tokens: &'a [i32], // [B]
    pub kv: &'a [f32],     // [nl,2,B,S,H,D] flattened, read-only
    pub mask: &'a [f32],   // [B,S] (current position NOT set)
    pub pos: &'a [i32],    // [B]
}

/// Outputs of one decode step.
pub struct DecodeOutputs {
    pub logits: Vec<f32>, // [B,V]
    pub k_new: Vec<f32>,  // [nl,B,H,D] — rust writes these at pos
    pub v_new: Vec<f32>,  // [nl,B,H,D]
    pub scores: Vec<f32>, // [B,S] Eq.2 relevance over cache rows
    pub timing: CallTiming,
}

pub struct DecodeProgram {
    exe: PjRtLoadedExecutable,
    pub batch: usize,
    pub kv_len: usize,
    pub r_budget: usize,
    pub model: ModelSpec,
}

impl DecodeProgram {
    pub fn new(
        exe: PjRtLoadedExecutable,
        batch: usize,
        kv_len: usize,
        r_budget: usize,
        model: ModelSpec,
    ) -> Self {
        DecodeProgram { exe, batch, kv_len, r_budget, model }
    }

    /// Total floats in the KV cache array for this bucket.
    pub fn kv_floats(&self) -> usize {
        self.model.n_layers * 2 * self.batch * self.kv_len * self.model.n_heads * self.model.d_head
    }

    pub fn run(&self, inp: &DecodeInputs) -> Result<DecodeOutputs> {
        let (b, s) = (self.batch, self.kv_len);
        let m = &self.model;
        self.check_len("tokens", inp.tokens.len(), b)?;
        self.check_len("kv", inp.kv.len(), self.kv_floats())?;
        self.check_len("mask", inp.mask.len(), b * s)?;
        self.check_len("pos", inp.pos.len(), b)?;

        let t0 = Instant::now();
        let args: Vec<Literal> = vec![
            lit_i32(&[b], inp.tokens)?,
            lit_f32(&[m.n_layers, 2, b, s, m.n_heads, m.d_head], inp.kv)?,
            lit_f32(&[b, s], inp.mask)?,
            lit_i32(&[b], inp.pos)?,
        ];
        let upload = t0.elapsed();

        let t1 = Instant::now();
        let result = self.exe.execute::<Literal>(&args)?;
        let execute = t1.elapsed();

        let t2 = Instant::now();
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 4 {
            return Err(Error::Engine(format!(
                "decode returned {} outputs, expected 4",
                parts.len()
            )));
        }
        let mut it = parts.into_iter();
        let logits = to_vec_f32(&it.next().unwrap())?;
        let k_new = to_vec_f32(&it.next().unwrap())?;
        let v_new = to_vec_f32(&it.next().unwrap())?;
        let scores = to_vec_f32(&it.next().unwrap())?;
        let download = t2.elapsed();

        debug_assert_eq!(k_new.len(), m.n_layers * b * m.n_heads * m.d_head);
        Ok(DecodeOutputs {
            logits,
            k_new,
            v_new,
            scores,
            timing: CallTiming { upload, execute, download },
        })
    }

    fn check_len(&self, name: &str, got: usize, want: usize) -> Result<()> {
        if got != want {
            return Err(Error::Engine(format!(
                "decode input '{name}': got {got} elements, expected {want}"
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Outputs of a prefill call.
pub struct PrefillOutputs {
    pub logits_last: Vec<f32>, // [B,V]
    pub kv: Vec<f32>,          // [nl,2,B,L,H,D]
    pub scores_last: Vec<f32>, // [B,L]
    pub timing: CallTiming,
}

pub struct PrefillProgram {
    exe: PjRtLoadedExecutable,
    pub batch: usize,
    pub len: usize,
    pub model: ModelSpec,
}

impl PrefillProgram {
    pub fn new(exe: PjRtLoadedExecutable, batch: usize, len: usize, model: ModelSpec) -> Self {
        PrefillProgram { exe, batch, len, model }
    }

    /// Run prefill over right-padded `tokens` ([B, L]) with valid `lengths`.
    pub fn run(&self, tokens: &[i32], lengths: &[i32]) -> Result<PrefillOutputs> {
        let (b, l) = (self.batch, self.len);
        if tokens.len() != b * l || lengths.len() != b {
            return Err(Error::Engine(format!(
                "prefill input shapes: tokens {} (want {}), lengths {} (want {b})",
                tokens.len(),
                b * l,
                lengths.len()
            )));
        }
        let t0 = Instant::now();
        let args = vec![lit_i32(&[b, l], tokens)?, lit_i32(&[b], lengths)?];
        let upload = t0.elapsed();

        let t1 = Instant::now();
        let result = self.exe.execute::<Literal>(&args)?;
        let execute = t1.elapsed();

        let t2 = Instant::now();
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Engine(format!(
                "prefill returned {} outputs, expected 3",
                parts.len()
            )));
        }
        let mut it = parts.into_iter();
        let logits_last = to_vec_f32(&it.next().unwrap())?;
        let kv = to_vec_f32(&it.next().unwrap())?;
        let scores_last = to_vec_f32(&it.next().unwrap())?;
        let download = t2.elapsed();

        Ok(PrefillOutputs {
            logits_last,
            kv,
            scores_last,
            timing: CallTiming { upload, execute, download },
        })
    }
}
