//! Runtime layer: loads AOT HLO artifacts and executes them via the
//! PJRT CPU client (`xla` crate). Python never runs here.

pub mod artifacts;
pub mod client;
pub mod literal;
pub mod program;

pub use artifacts::{Manifest, ModelSpec, ProgramKind, ProgramSpec};
pub use client::Runtime;
pub use program::{CallTiming, DecodeInputs, DecodeOutputs, DecodeProgram, PrefillOutputs, PrefillProgram};
