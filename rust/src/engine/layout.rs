//! KV buffer layout helpers.
//!
//! Decode cache layout (matches python `decode_apply`):
//!     kv [nl, 2, B, S, H, D]  (f32, row-major)
//! Prefill output layout:
//!     kv [nl, 2, B, L, H, D]
//! Row bundle (frozen payloads, matches `frozen_rows`):
//!     [nl, 2, H, D] per token.

use crate::runtime::ModelSpec;

/// Geometry of one decode cache buffer.
#[derive(Debug, Clone, Copy)]
pub struct KvGeom {
    pub nl: usize,
    pub b: usize,
    pub s: usize,
    pub hd: usize, // H * D floats per row per plane
}

impl KvGeom {
    pub fn new(m: &ModelSpec, b: usize, s: usize) -> Self {
        KvGeom { nl: m.n_layers, b, s, hd: m.n_heads * m.d_head }
    }

    pub fn planes(&self) -> usize {
        self.nl * 2
    }

    pub fn floats(&self) -> usize {
        self.planes() * self.b * self.s * self.hd
    }

    pub fn row_floats(&self) -> usize {
        self.planes() * self.hd
    }

    /// Offset of (plane p, slot b, position pos) in the flat buffer.
    #[inline]
    pub fn offset(&self, p: usize, slot: usize, pos: usize) -> usize {
        ((p * self.b + slot) * self.s + pos) * self.hd
    }
}

/// Copy a prefill KV ([nl,2,1,L,H,D], `valid` rows used) into slot
/// `slot` of a decode cache buffer ([nl,2,B,S,H,D]).
pub fn insert_prefill(
    dst: &mut [f32],
    geom: &KvGeom,
    slot: usize,
    prefill_kv: &[f32],
    l_bucket: usize,
    valid: usize,
) {
    debug_assert_eq!(dst.len(), geom.floats());
    debug_assert_eq!(prefill_kv.len(), geom.planes() * l_bucket * geom.hd);
    debug_assert!(valid <= l_bucket && valid <= geom.s);
    for p in 0..geom.planes() {
        let src = &prefill_kv[p * l_bucket * geom.hd..][..valid * geom.hd];
        let d0 = geom.offset(p, slot, 0);
        dst[d0..d0 + valid * geom.hd].copy_from_slice(src);
    }
}

/// Scatter a frozen row bundle ([nl,2,H,D]) back into the cache at
/// `pos`. Single-row path: kept for the emergency RR recovery restore
/// (and tests); plan execution goes through the batched
/// [`scatter_rows`].
pub fn scatter_row(dst: &mut [f32], geom: &KvGeom, slot: usize, pos: usize, row: &[f32]) {
    debug_assert_eq!(row.len(), geom.row_floats());
    for p in 0..geom.planes() {
        let d0 = geom.offset(p, slot, pos);
        dst[d0..d0 + geom.hd].copy_from_slice(&row[p * geom.hd..][..geom.hd]);
    }
}

/// A run of consecutive cache positions in one batch lane: `len` rows
/// starting at `start`. Produced by [`coalesce_runs`] from a plan's
/// sorted position list; consumed by the batched transfer helpers
/// below, which issue one span copy per (plane, run) instead of one
/// per (plane, row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PosRun {
    pub start: usize,
    pub len: usize,
}

impl PosRun {
    pub fn positions(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.len
    }
}

/// Coalesce a strictly-ascending position list into maximal contiguous
/// runs. The number of runs is the number of span copies each plane
/// pays — the batching win `metrics::BatchStats` records.
pub fn coalesce_runs(sorted: &[usize]) -> Vec<PosRun> {
    debug_assert!(
        sorted.windows(2).all(|w| w[0] < w[1]),
        "positions must be sorted strictly ascending"
    );
    let mut runs: Vec<PosRun> = Vec::new();
    for &p in sorted {
        match runs.last_mut() {
            Some(r) if r.start + r.len == p => r.len += 1,
            _ => runs.push(PosRun { start: p, len: 1 }),
        }
    }
    runs
}

/// Split coalesced runs into per-shard position lists: `shard_of`
/// maps each position to its shard in `0..n`. A shard split is a run
/// split — a `Range`-partitioned run cuts at chunk boundaries into
/// shard-contiguous spans, a `Hash`-partitioned run fans its positions
/// round-robin. Runs are walked in order, so each shard's list stays
/// strictly ascending (ready for that shard's own `coalesce_runs`).
pub fn split_runs(runs: &[PosRun], n: usize, shard_of: impl Fn(usize) -> usize) -> Vec<Vec<usize>> {
    let n = n.max(1);
    let mut out: Vec<Vec<usize>> = (0..n).map(|_| Vec::new()).collect();
    for run in runs {
        for pos in run.positions() {
            let s = shard_of(pos);
            debug_assert!(s < n, "shard_of({pos}) = {s} out of range for {n} shards");
            out[s.min(n - 1)].push(pos);
        }
    }
    out
}

/// Batched scatter: write row bundles back into the cache for every
/// position covered by `runs`, one destination `copy_from_slice` span
/// per (plane, run). Bundles are first assembled into a contiguous
/// per-run staging buffer — on real hardware that is the pinned host
/// buffer a single H2D DMA reads from — so the cache sees
/// `planes * runs` span writes instead of `planes * rows` row writes.
/// `rows[i]` is the bundle for the i-th position in run order.
pub fn scatter_rows(
    dst: &mut [f32],
    geom: &KvGeom,
    slot: usize,
    runs: &[PosRun],
    rows: &[Vec<f32>],
) {
    debug_assert_eq!(rows.len(), runs.iter().map(|r| r.len).sum::<usize>());
    let mut scratch: Vec<f32> = Vec::new();
    let mut base = 0usize;
    for run in runs {
        for p in 0..geom.planes() {
            scratch.clear();
            for row in &rows[base..base + run.len] {
                debug_assert_eq!(row.len(), geom.row_floats());
                scratch.extend_from_slice(&row[p * geom.hd..][..geom.hd]);
            }
            let d0 = geom.offset(p, slot, run.start);
            dst[d0..d0 + run.len * geom.hd].copy_from_slice(&scratch);
        }
        base += run.len;
    }
}

/// Batched gather: read the row bundles for every position covered by
/// `runs` out of the cache — one source span per (plane, run) — and
/// split them into per-position bundles for stashing. Returns bundles
/// in run order.
pub fn gather_rows(src: &[f32], geom: &KvGeom, slot: usize, runs: &[PosRun]) -> Vec<Vec<f32>> {
    let n: usize = runs.iter().map(|r| r.len).sum();
    let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; geom.row_floats()]).collect();
    let mut base = 0usize;
    for run in runs {
        for p in 0..geom.planes() {
            let s0 = geom.offset(p, slot, run.start);
            let span = &src[s0..s0 + run.len * geom.hd];
            for (j, chunk) in span.chunks_exact(geom.hd).enumerate() {
                out[base + j][p * geom.hd..][..geom.hd].copy_from_slice(chunk);
            }
        }
        base += run.len;
    }
    out
}

/// Batched zero: clear every row covered by `runs`, one `fill` span
/// per (plane, run) — the "device" side of a batched freeze.
pub fn zero_rows(dst: &mut [f32], geom: &KvGeom, slot: usize, runs: &[PosRun]) {
    for run in runs {
        for p in 0..geom.planes() {
            let d0 = geom.offset(p, slot, run.start);
            dst[d0..d0 + run.len * geom.hd].fill(0.0);
        }
    }
}

/// Gather a row bundle out of the cache (tests / diagnostics).
pub fn gather_row(src: &[f32], geom: &KvGeom, slot: usize, pos: usize) -> Vec<f32> {
    let mut row = vec![0.0f32; geom.row_floats()];
    for p in 0..geom.planes() {
        let s0 = geom.offset(p, slot, pos);
        row[p * geom.hd..][..geom.hd].copy_from_slice(&src[s0..s0 + geom.hd]);
    }
    row
}

/// Zero a row in the cache (the "device" side of a freeze: the row's
/// data leaves the active cache entirely, recoverable only from the
/// host-side FrozenStore).
pub fn zero_row(dst: &mut [f32], geom: &KvGeom, slot: usize, pos: usize) {
    for p in 0..geom.planes() {
        let d0 = geom.offset(p, slot, pos);
        dst[d0..d0 + geom.hd].fill(0.0);
    }
}

/// Write the decode step's new KV row into the cache at `pos`:
/// `k_new`/`v_new` are the graph outputs, layout `[nl, B, H, D]`.
pub fn write_new_row(
    dst: &mut [f32],
    geom: &KvGeom,
    slot: usize,
    pos: usize,
    k_new: &[f32],
    v_new: &[f32],
) {
    debug_assert_eq!(k_new.len(), geom.nl * geom.b * geom.hd);
    debug_assert_eq!(v_new.len(), k_new.len());
    for l in 0..geom.nl {
        let src = (l * geom.b + slot) * geom.hd;
        let dk = geom.offset(l * 2, slot, pos);
        dst[dk..dk + geom.hd].copy_from_slice(&k_new[src..src + geom.hd]);
        let dv = geom.offset(l * 2 + 1, slot, pos);
        dst[dv..dv + geom.hd].copy_from_slice(&v_new[src..src + geom.hd]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            vocab: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            max_len: 64,
            kv_row_floats: 2 * 2 * 2 * 4,
        }
    }

    #[test]
    fn geometry() {
        let g = KvGeom::new(&spec(), 2, 16);
        assert_eq!(g.planes(), 4);
        assert_eq!(g.floats(), 4 * 2 * 16 * 8);
        assert_eq!(g.row_floats(), 32);
        assert_eq!(g.offset(0, 0, 0), 0);
        assert_eq!(g.offset(0, 0, 1), 8);
        assert_eq!(g.offset(0, 1, 0), 16 * 8);
        assert_eq!(g.offset(1, 0, 0), 2 * 16 * 8);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = KvGeom::new(&spec(), 2, 16);
        let mut kv = vec![0.0f32; g.floats()];
        let row: Vec<f32> = (0..g.row_floats()).map(|i| i as f32 + 1.0).collect();
        scatter_row(&mut kv, &g, 1, 5, &row);
        assert_eq!(gather_row(&kv, &g, 1, 5), row);
        // other slot/pos untouched
        assert!(gather_row(&kv, &g, 0, 5).iter().all(|&v| v == 0.0));
        assert!(gather_row(&kv, &g, 1, 4).iter().all(|&v| v == 0.0));
        zero_row(&mut kv, &g, 1, 5);
        assert!(kv.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn coalesce_runs_merges_contiguous_positions() {
        assert_eq!(coalesce_runs(&[]), vec![]);
        assert_eq!(coalesce_runs(&[5]), vec![PosRun { start: 5, len: 1 }]);
        assert_eq!(
            coalesce_runs(&[2, 3, 4, 7, 9, 10]),
            vec![
                PosRun { start: 2, len: 3 },
                PosRun { start: 7, len: 1 },
                PosRun { start: 9, len: 2 },
            ]
        );
        let total: usize = coalesce_runs(&[0, 1, 2, 3]).iter().map(|r| r.len).sum();
        assert_eq!(total, 4);
        assert_eq!(PosRun { start: 9, len: 2 }.positions().collect::<Vec<_>>(), vec![9, 10]);
    }

    #[test]
    fn split_runs_covers_each_position_once() {
        let positions = vec![2usize, 3, 4, 5, 9, 12, 13];
        let runs = coalesce_runs(&positions);
        // hash partition: round-robin across 3 shards
        let hash = split_runs(&runs, 3, |p| p % 3);
        assert_eq!(hash[0], vec![3, 9, 12]);
        assert_eq!(hash[1], vec![4, 13]);
        assert_eq!(hash[2], vec![2, 5]);
        // range partition (chunk 4): run [2..6) splits at the 4 boundary
        let range = split_runs(&runs, 2, |p| (p / 4) % 2);
        assert_eq!(range[0], vec![2, 3, 9]);
        assert_eq!(range[1], vec![4, 5, 12, 13]);
        for per in [&hash, &range] {
            let mut all: Vec<usize> = per.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, positions, "positions lost or duplicated");
            for shard in per.iter() {
                assert!(shard.windows(2).all(|w| w[0] < w[1]), "per-shard order broken");
            }
        }
        // n = 1 degenerates to the full position list
        assert_eq!(split_runs(&runs, 1, |_| 0)[0], positions);
        assert!(split_runs(&[], 4, |p| p % 4).iter().all(Vec::is_empty));
    }

    #[test]
    fn batched_scatter_gather_match_single_row_path() {
        let g = KvGeom::new(&spec(), 2, 16);
        let positions = vec![1usize, 2, 3, 6, 11, 12];
        let runs = coalesce_runs(&positions);
        let rows: Vec<Vec<f32>> = positions
            .iter()
            .map(|&p| (0..g.row_floats()).map(|i| (p * 100 + i) as f32).collect())
            .collect();

        // batched scatter == per-row scatter
        let mut batched = vec![0.0f32; g.floats()];
        scatter_rows(&mut batched, &g, 1, &runs, &rows);
        let mut single = vec![0.0f32; g.floats()];
        for (i, &p) in positions.iter().enumerate() {
            scatter_row(&mut single, &g, 1, p, &rows[i]);
        }
        assert_eq!(batched, single);

        // batched gather == per-row gather, in run order
        let gathered = gather_rows(&batched, &g, 1, &runs);
        assert_eq!(gathered.len(), positions.len());
        for (i, &p) in positions.iter().enumerate() {
            assert_eq!(gathered[i], gather_row(&batched, &g, 1, p), "pos {p}");
        }

        // batched zero == per-row zero
        zero_rows(&mut batched, &g, 1, &runs);
        for &p in &positions {
            single_zero_check(&batched, &g, 1, p);
        }
        // untouched lane stays zero throughout
        assert!(gather_row(&batched, &g, 0, 3).iter().all(|&v| v == 0.0));
    }

    fn single_zero_check(kv: &[f32], g: &KvGeom, slot: usize, pos: usize) {
        assert!(
            gather_row(kv, g, slot, pos).iter().all(|&v| v == 0.0),
            "pos {pos} not zeroed"
        );
    }

    #[test]
    fn batched_helpers_handle_empty_plans() {
        let g = KvGeom::new(&spec(), 1, 8);
        let mut kv = vec![7.0f32; g.floats()];
        scatter_rows(&mut kv, &g, 0, &[], &[]);
        zero_rows(&mut kv, &g, 0, &[]);
        assert!(gather_rows(&kv, &g, 0, &[]).is_empty());
        assert!(kv.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn write_new_row_hits_k_and_v_planes() {
        let g = KvGeom::new(&spec(), 2, 16);
        let mut kv = vec![0.0f32; g.floats()];
        // k_new/v_new: [nl, B, H*D]
        let k_new: Vec<f32> = (0..g.nl * g.b * g.hd).map(|i| i as f32 + 1.0).collect();
        let v_new: Vec<f32> = (0..g.nl * g.b * g.hd).map(|i| -(i as f32) - 1.0).collect();
        write_new_row(&mut kv, &g, 1, 7, &k_new, &v_new);
        let row = gather_row(&kv, &g, 1, 7); // [nl,2,H,D] flattened
        for l in 0..g.nl {
            let src = (l * g.b + 1) * g.hd;
            assert_eq!(&row[(l * 2) * g.hd..][..g.hd], &k_new[src..src + g.hd]);
            assert_eq!(&row[(l * 2 + 1) * g.hd..][..g.hd], &v_new[src..src + g.hd]);
        }
        // slot 0 untouched
        assert!(gather_row(&kv, &g, 0, 7).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prefill_insertion_lands_in_slot() {
        let g = KvGeom::new(&spec(), 2, 16);
        let l_bucket = 8;
        let valid = 5;
        let prefill: Vec<f32> = (0..g.planes() * l_bucket * g.hd).map(|i| i as f32).collect();
        let mut kv = vec![0.0f32; g.floats()];
        insert_prefill(&mut kv, &g, 1, &prefill, l_bucket, valid);
        // row 0 of plane 0, slot 1 == prefill row 0 of plane 0
        assert_eq!(gather_row(&kv, &g, 1, 0)[..g.hd], prefill[..g.hd]);
        // beyond valid is zero
        assert!(gather_row(&kv, &g, 1, valid).iter().all(|&v| v == 0.0));
        // slot 0 untouched
        assert!(gather_row(&kv, &g, 0, 0).iter().all(|&v| v == 0.0));
    }
}
