//! KV buffer layout helpers.
//!
//! Decode cache layout (matches python `decode_apply`):
//!     kv [nl, 2, B, S, H, D]  (f32, row-major)
//! Prefill output layout:
//!     kv [nl, 2, B, L, H, D]
//! Row bundle (frozen payloads, matches `frozen_rows`):
//!     [nl, 2, H, D] per token.

use crate::runtime::ModelSpec;

/// Geometry of one decode cache buffer.
#[derive(Debug, Clone, Copy)]
pub struct KvGeom {
    pub nl: usize,
    pub b: usize,
    pub s: usize,
    pub hd: usize, // H * D floats per row per plane
}

impl KvGeom {
    pub fn new(m: &ModelSpec, b: usize, s: usize) -> Self {
        KvGeom { nl: m.n_layers, b, s, hd: m.n_heads * m.d_head }
    }

    pub fn planes(&self) -> usize {
        self.nl * 2
    }

    pub fn floats(&self) -> usize {
        self.planes() * self.b * self.s * self.hd
    }

    pub fn row_floats(&self) -> usize {
        self.planes() * self.hd
    }

    /// Offset of (plane p, slot b, position pos) in the flat buffer.
    #[inline]
    pub fn offset(&self, p: usize, slot: usize, pos: usize) -> usize {
        ((p * self.b + slot) * self.s + pos) * self.hd
    }
}

/// Copy a prefill KV ([nl,2,1,L,H,D], `valid` rows used) into slot
/// `slot` of a decode cache buffer ([nl,2,B,S,H,D]).
pub fn insert_prefill(
    dst: &mut [f32],
    geom: &KvGeom,
    slot: usize,
    prefill_kv: &[f32],
    l_bucket: usize,
    valid: usize,
) {
    debug_assert_eq!(dst.len(), geom.floats());
    debug_assert_eq!(prefill_kv.len(), geom.planes() * l_bucket * geom.hd);
    debug_assert!(valid <= l_bucket && valid <= geom.s);
    for p in 0..geom.planes() {
        let src = &prefill_kv[p * l_bucket * geom.hd..][..valid * geom.hd];
        let d0 = geom.offset(p, slot, 0);
        dst[d0..d0 + valid * geom.hd].copy_from_slice(src);
    }
}

/// Scatter a frozen row bundle ([nl,2,H,D]) back into the cache at
/// `pos` (host-side emergency restore — the RR recovery path).
pub fn scatter_row(dst: &mut [f32], geom: &KvGeom, slot: usize, pos: usize, row: &[f32]) {
    debug_assert_eq!(row.len(), geom.row_floats());
    for p in 0..geom.planes() {
        let d0 = geom.offset(p, slot, pos);
        dst[d0..d0 + geom.hd].copy_from_slice(&row[p * geom.hd..][..geom.hd]);
    }
}

/// Gather a row bundle out of the cache (tests / diagnostics).
pub fn gather_row(src: &[f32], geom: &KvGeom, slot: usize, pos: usize) -> Vec<f32> {
    let mut row = vec![0.0f32; geom.row_floats()];
    for p in 0..geom.planes() {
        let s0 = geom.offset(p, slot, pos);
        row[p * geom.hd..][..geom.hd].copy_from_slice(&src[s0..s0 + geom.hd]);
    }
    row
}

/// Zero a row in the cache (the "device" side of a freeze: the row's
/// data leaves the active cache entirely, recoverable only from the
/// host-side FrozenStore).
pub fn zero_row(dst: &mut [f32], geom: &KvGeom, slot: usize, pos: usize) {
    for p in 0..geom.planes() {
        let d0 = geom.offset(p, slot, pos);
        dst[d0..d0 + geom.hd].fill(0.0);
    }
}

/// Write the decode step's new KV row into the cache at `pos`:
/// `k_new`/`v_new` are the graph outputs, layout `[nl, B, H, D]`.
pub fn write_new_row(
    dst: &mut [f32],
    geom: &KvGeom,
    slot: usize,
    pos: usize,
    k_new: &[f32],
    v_new: &[f32],
) {
    debug_assert_eq!(k_new.len(), geom.nl * geom.b * geom.hd);
    debug_assert_eq!(v_new.len(), k_new.len());
    for l in 0..geom.nl {
        let src = (l * geom.b + slot) * geom.hd;
        let dk = geom.offset(l * 2, slot, pos);
        dst[dk..dk + geom.hd].copy_from_slice(&k_new[src..src + geom.hd]);
        let dv = geom.offset(l * 2 + 1, slot, pos);
        dst[dv..dv + geom.hd].copy_from_slice(&v_new[src..src + geom.hd]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            vocab: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            max_len: 64,
            kv_row_floats: 2 * 2 * 2 * 4,
        }
    }

    #[test]
    fn geometry() {
        let g = KvGeom::new(&spec(), 2, 16);
        assert_eq!(g.planes(), 4);
        assert_eq!(g.floats(), 4 * 2 * 16 * 8);
        assert_eq!(g.row_floats(), 32);
        assert_eq!(g.offset(0, 0, 0), 0);
        assert_eq!(g.offset(0, 0, 1), 8);
        assert_eq!(g.offset(0, 1, 0), 16 * 8);
        assert_eq!(g.offset(1, 0, 0), 2 * 16 * 8);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = KvGeom::new(&spec(), 2, 16);
        let mut kv = vec![0.0f32; g.floats()];
        let row: Vec<f32> = (0..g.row_floats()).map(|i| i as f32 + 1.0).collect();
        scatter_row(&mut kv, &g, 1, 5, &row);
        assert_eq!(gather_row(&kv, &g, 1, 5), row);
        // other slot/pos untouched
        assert!(gather_row(&kv, &g, 0, 5).iter().all(|&v| v == 0.0));
        assert!(gather_row(&kv, &g, 1, 4).iter().all(|&v| v == 0.0));
        zero_row(&mut kv, &g, 1, 5);
        assert!(kv.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn write_new_row_hits_k_and_v_planes() {
        let g = KvGeom::new(&spec(), 2, 16);
        let mut kv = vec![0.0f32; g.floats()];
        // k_new/v_new: [nl, B, H*D]
        let k_new: Vec<f32> = (0..g.nl * g.b * g.hd).map(|i| i as f32 + 1.0).collect();
        let v_new: Vec<f32> = (0..g.nl * g.b * g.hd).map(|i| -(i as f32) - 1.0).collect();
        write_new_row(&mut kv, &g, 1, 7, &k_new, &v_new);
        let row = gather_row(&kv, &g, 1, 7); // [nl,2,H,D] flattened
        for l in 0..g.nl {
            let src = (l * g.b + 1) * g.hd;
            assert_eq!(&row[(l * 2) * g.hd..][..g.hd], &k_new[src..src + g.hd]);
            assert_eq!(&row[(l * 2 + 1) * g.hd..][..g.hd], &v_new[src..src + g.hd]);
        }
        // slot 0 untouched
        assert!(gather_row(&kv, &g, 0, 7).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prefill_insertion_lands_in_slot() {
        let g = KvGeom::new(&spec(), 2, 16);
        let l_bucket = 8;
        let valid = 5;
        let prefill: Vec<f32> = (0..g.planes() * l_bucket * g.hd).map(|i| i as f32).collect();
        let mut kv = vec![0.0f32; g.floats()];
        insert_prefill(&mut kv, &g, 1, &prefill, l_bucket, valid);
        // row 0 of plane 0, slot 1 == prefill row 0 of plane 0
        assert_eq!(gather_row(&kv, &g, 1, 0)[..g.hd], prefill[..g.hd]);
        // beyond valid is zero
        assert!(gather_row(&kv, &g, 1, valid).iter().all(|&v| v == 0.0));
        // slot 0 untouched
        assert!(gather_row(&kv, &g, 0, 0).iter().all(|&v| v == 0.0));
    }
}
