//! Per-sequence generation state: tokens, activity mask, tiered
//! frozen-row store, policy, sampler, entropy monitor and step trace.
//! Shared by the single-sequence generator and the batched coordinator
//! — the KV *data* itself is owned by whichever engine drives the
//! session.

use std::time::{Duration, Instant};

use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kv::policy::{KvPolicy, Plan, UnfreezeScope};
use crate::metrics::flight::now_us;
use crate::metrics::{BatchStats, Histogram, PlanLatency, Registry, StepSegments, StepSpan};
use crate::model::logits::{logits_entropy, top1_prob};
use crate::model::sampling::Sampler;
use crate::offload::{OffloadSummary, ShardedStore};
use crate::recovery::{Action, EntropyMonitor, RecoveryLadder};
use crate::runtime::CallTiming;

/// One decode step's trace record (drives Figure 1 and §Perf).
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    /// tokens in the sequence after this step
    pub total: usize,
    /// active KV rows after this step
    pub active: usize,
    pub frozen: usize,
    pub entropy: f32,
    pub froze: usize,
    pub restored: usize,
    pub upload: Duration,
    pub execute: Duration,
    pub download: Duration,
    /// rust-side bookkeeping (plan + stash + mask updates)
    pub host: Duration,
    pub recovery_level: u8,
    /// wall-clock attribution of this step (plan/restore/freeze/compute
    /// on the shared flight-recorder timebase); segments sum exactly to
    /// the step's measured wall-clock by construction
    pub span: StepSpan,
}


pub struct Session {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub policy: Box<dyn KvPolicy>,
    /// Sharded tiered frozen-row storage; `OffloadConfig::shards = 1`
    /// degenerates to the single-store behavior.
    pub store: ShardedStore,
    /// activity mask [S] for this session's decode bucket
    pub mask: Vec<f32>,
    /// rows written to the cache so far (== next write position)
    pub len: usize,
    pub sampler: Sampler,
    pub last_logits: Vec<f32>,
    pub step: u64,
    pub trace: Vec<StepRecord>,
    pub monitor: Option<EntropyMonitor>,
    pub ladder: Option<RecoveryLadder>,
    /// plan-batching telemetry: rows/spans per freeze & restore batch
    pub batch: BatchStats,
    /// per-step policy control-plane time (`plan` + `observe`), the
    /// measurable side of the indexed policy's O(work) contract
    pub plan_hist: Histogram,
    /// this step's `plan` time, folded into `plan_hist` with the
    /// matching `observe` time in [`Session::absorb`]
    plan_time_pending: Duration,
    /// cumulative step-segment attribution; `coverage()` is exactly 1
    /// because the five segments partition the measured wall-clock
    pub segments: StepSegments,
    /// per-step wall-clock distribution (feeds `asrkf_step_us`)
    step_hist: Histogram,
    seg_plan_hist: Histogram,
    seg_restore_hist: Histogram,
    seg_wait_hist: Histogram,
    seg_compute_hist: Histogram,
    seg_freeze_hist: Histogram,
    /// timestamps staged by `apply_plan` on the flight-recorder
    /// timebase, consumed by the matching `absorb`
    seg_start_us: u64,
    seg_mid_us: u64,
    seg_plan_us: u64,
    seg_restore_us: u64,
    seg_freeze_us: u64,
    /// wall time `apply_plan` spent blocked on in-flight speculative
    /// restores, carved out of the restore/freeze segments above
    seg_wait_us: u64,
    /// sampler stream positions indexed by generated-token count (RR rewind)
    draws_at: Vec<u64>,
    s_capacity: usize,
}

impl Session {
    /// Errors surface unusable offload configurations (a per-shard hot
    /// budget below one row) before any token is generated.
    pub fn new(
        id: u64,
        prompt_tokens: Vec<i32>,
        max_new: usize,
        policy: Box<dyn KvPolicy>,
        cfg: &EngineConfig,
        s_capacity: usize,
        row_floats: usize,
    ) -> Result<Self> {
        Session::build(id, prompt_tokens, max_new, policy, cfg, s_capacity, row_floats, false)
    }

    /// Like [`Session::new`], but re-attaches to a persistent spill
    /// directory (`OffloadConfig::spill_persist`) and **recovers** the
    /// previous life's spilled rows instead of reclaiming them: they
    /// re-enter the store as restorable frozen rows, counted in the
    /// offload summary (`recovered_rows` / `recovery_errors`). A
    /// recovered position the new session re-freezes is superseded by
    /// the fresh row; recovered positions beyond this session's KV
    /// capacity can never be restored into the cache and are reclaimed
    /// with accounting at construction. Without `spill_persist` this
    /// is identical to `new`.
    #[allow(clippy::too_many_arguments)]
    pub fn resume(
        id: u64,
        prompt_tokens: Vec<i32>,
        max_new: usize,
        policy: Box<dyn KvPolicy>,
        cfg: &EngineConfig,
        s_capacity: usize,
        row_floats: usize,
    ) -> Result<Self> {
        Session::build(id, prompt_tokens, max_new, policy, cfg, s_capacity, row_floats, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        id: u64,
        prompt_tokens: Vec<i32>,
        max_new: usize,
        policy: Box<dyn KvPolicy>,
        cfg: &EngineConfig,
        s_capacity: usize,
        row_floats: usize,
        resume_spill: bool,
    ) -> Result<Self> {
        let (monitor, ladder) = if cfg.recovery.enabled {
            (
                Some(EntropyMonitor::new(cfg.recovery.clone())),
                Some(RecoveryLadder::new(cfg.recovery.clone())),
            )
        } else {
            (None, None)
        };
        let mut store = if resume_spill {
            ShardedStore::resume(row_floats, cfg.offload.clone())?
        } else {
            ShardedStore::new(row_floats, cfg.offload.clone())?
        };
        if resume_spill {
            // rows recovered beyond this session's KV capacity can
            // never scatter back into the cache: reclaim them with
            // accounting instead of leaving unrestorable residents
            let oob: Vec<usize> = store.positions().filter(|&p| p >= s_capacity).collect();
            if !oob.is_empty() {
                log::warn!(
                    "session {id}: reclaiming {} recovered rows beyond KV capacity {s_capacity}",
                    oob.len()
                );
                for p in oob {
                    store.drop_row(p)?;
                }
            }
        }
        Ok(Session {
            id,
            prompt_len: prompt_tokens.len(),
            tokens: prompt_tokens,
            max_new,
            policy,
            store,
            mask: vec![0.0; s_capacity],
            len: 0,
            sampler: Sampler::new(cfg.sampling.clone()),
            last_logits: Vec::new(),
            step: 0,
            trace: Vec::new(),
            monitor,
            ladder,
            batch: BatchStats::default(),
            plan_hist: Histogram::default(),
            plan_time_pending: Duration::ZERO,
            segments: StepSegments::default(),
            step_hist: Histogram::default(),
            seg_plan_hist: Histogram::default(),
            seg_restore_hist: Histogram::default(),
            seg_wait_hist: Histogram::default(),
            seg_compute_hist: Histogram::default(),
            seg_freeze_hist: Histogram::default(),
            seg_start_us: 0,
            seg_mid_us: 0,
            seg_plan_us: 0,
            seg_restore_us: 0,
            seg_freeze_us: 0,
            seg_wait_us: 0,
            draws_at: Vec::new(),
            s_capacity,
        })
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn is_done(&self) -> bool {
        self.generated() >= self.max_new || self.len >= self.s_capacity
    }

    pub fn generated_text(&self) -> String {
        crate::model::tokenizer::decode(&self.tokens[self.prompt_len..])
    }

    /// Record prefill results: `valid` rows live, logits for sampling.
    pub fn seed_prefill(&mut self, logits_last: Vec<f32>, scores_last: &[f32], valid: usize) {
        for m in self.mask.iter_mut().take(valid) {
            *m = 1.0;
        }
        self.len = valid;
        self.policy.on_prefill(&scores_last[..valid], valid);
        self.last_logits = logits_last;
    }

    /// Sample the next token (records the sampler position for RR).
    pub fn next_token(&mut self) -> i32 {
        self.draws_at.push(self.sampler.checkpoint_draws());
        self.sampler.sample(&self.last_logits) as i32
    }

    /// Ask the policy for this step's plan and apply the data movement
    /// to the (engine-owned) KV cache as per-slot batches: all restores
    /// scatter in one pass (one span copy per plane per contiguous
    /// run), all freezes gather + zero the same way. Mask is updated
    /// (restores -> 1, freezes -> 0). `slot` selects the batch lane.
    ///
    /// `plan` is a caller-owned buffer refilled in place
    /// ([`KvPolicy::plan_into`]) — engines keep one alive across steps
    /// so plan construction allocates nothing in steady state. The
    /// policy's plan time is recorded into [`Session::plan_hist`]
    /// (together with the following `observe` in [`Session::absorb`]).
    ///
    /// Restores land on staged hot rows whenever the prefetch path ran
    /// ahead of the thaw (see [`Session::absorb`]); errors surface
    /// storage invariant breaches (missing payload, double freeze) and
    /// spill-tier I/O failures. Batch sizes and span counts are
    /// recorded in [`Session::batch`].
    pub fn apply_plan(
        &mut self,
        kv: &mut [f32],
        geom: &crate::engine::layout::KvGeom,
        slot: usize,
        r_budget: usize,
        plan: &mut Plan,
    ) -> Result<()> {
        use crate::engine::layout::{coalesce_runs, gather_rows, scatter_rows, zero_rows};
        let s0 = now_us();
        let t_plan = Instant::now();
        self.policy.plan_into(self.step, self.len, r_budget, plan);
        self.plan_time_pending = t_plan.elapsed();
        let s1 = now_us();
        debug_assert!(
            plan.restore.windows(2).all(|w| w[0] < w[1]),
            "policy returned an unsorted restore list"
        );
        debug_assert!(
            plan.freeze.windows(2).all(|w| w[0] < w[1]),
            "policy returned an unsorted freeze list"
        );

        if !plan.restore.is_empty() {
            // parallel burst: the store splits the coalesced runs at
            // shard boundaries and takes each slice on its worker
            let fetched = self.store.take_batch(&plan.restore)?;
            let mut payloads = Vec::with_capacity(plan.restore.len());
            for (&pos, payload) in plan.restore.iter().zip(fetched) {
                payloads.push(payload.ok_or_else(|| {
                    Error::Offload(format!("restore of pos {pos} with no stashed payload"))
                })?);
            }
            let runs = coalesce_runs(&plan.restore);
            scatter_rows(kv, geom, slot, &runs, &payloads);
            for &pos in &plan.restore {
                self.mask[pos] = 1.0;
            }
            self.batch.record_restore(plan.restore.len(), runs.len());
        }
        // time blocked on in-flight speculative reads is reported as
        // restore *wait*, not restore work (clamped so the segments
        // still partition the wall clock exactly)
        let w_restore = self.store.take_wait_us();
        let s2 = now_us();

        if !plan.freeze.is_empty() {
            let runs = coalesce_runs(&plan.freeze);
            if plan.drop_payload {
                for &pos in &plan.freeze {
                    self.store.drop_row(pos)?; // irreversible baselines: data is gone
                }
            } else {
                let rows = gather_rows(kv, geom, slot, &runs);
                // tier admission is driven by the policy's predicted
                // thaw step (freeze step + Eq.3 duration)
                let items: Vec<(usize, Vec<f32>, u64)> = plan
                    .freeze
                    .iter()
                    .zip(rows)
                    .enumerate()
                    .map(|(i, (&pos, row))| {
                        let eta = plan.freeze_thaw_eta.get(i).copied().unwrap_or(self.step + 1);
                        (pos, row, eta)
                    })
                    .collect();
                self.store.stash_batch(items, self.step)?;
            }
            zero_rows(kv, geom, slot, &runs);
            for &pos in &plan.freeze {
                self.mask[pos] = 0.0;
            }
            self.batch.record_freeze(plan.freeze.len(), runs.len());
        }
        let w_freeze = self.store.take_wait_us();
        let s3 = now_us();
        // stage this step's attribution for the matching `absorb`:
        // everything between s3 and absorb's entry is the engine's
        // compute (upload + execute + download + sampling glue)
        let w_restore = w_restore.min(s2 - s1);
        let w_freeze = w_freeze.min(s3 - s2);
        self.seg_start_us = s0;
        self.seg_plan_us = s1 - s0;
        self.seg_restore_us = (s2 - s1) - w_restore;
        self.seg_freeze_us = (s3 - s2) - w_freeze;
        self.seg_wait_us = w_restore + w_freeze;
        self.seg_mid_us = s3;
        Ok(())
    }

    /// Adopt a re-sliced tier budget between decode steps (continuous
    /// batching: the coordinator reflows freed budget to occupied slots
    /// at step boundaries). Forwards to the store, which settles any
    /// outstanding speculative work first and demotes immediately on a
    /// shrink; must only be called between `apply_plan`/`absorb` pairs,
    /// the same boundary the batcher already schedules on. Errors mean
    /// the slice was unusable (below one hot row per shard) and the
    /// session's budgets are unchanged.
    pub fn reslice_budgets(
        &mut self,
        hot_budget_bytes: usize,
        cold_budget_bytes: usize,
    ) -> Result<()> {
        self.store.set_budgets(hot_budget_bytes, cold_budget_bytes)
    }

    /// Store summary overlaid with this session's plan-batching
    /// counters (batching happens in the engine's plan execution, so
    /// the store cannot report it itself).
    pub fn offload_summary(&self) -> OffloadSummary {
        let mut s = self.store.summary();
        s.restore_batch_rows = self.batch.restore_rows;
        s.restore_batch_spans = self.batch.restore_spans;
        s
    }

    /// Snapshot of the per-step policy control-plane cost.
    pub fn plan_latency(&self) -> PlanLatency {
        PlanLatency::from_histogram(&self.plan_hist)
    }

    /// Publish this session's monotone telemetry — store flows, plan
    /// latency, step timing split into segments, and plan-batching
    /// counters — into a long-lived registry. Called once per session
    /// (at retirement in batched serving, at end of generation on the
    /// single-session path); repeated accumulation is safe because
    /// every series here only grows. Point-in-time occupancy gauges
    /// are published separately by whoever owns the live view.
    pub fn publish_to_registry(&self, reg: &Registry) {
        reg.publish(|b| {
            self.store.publish_flows(b);
            b.counter_add("asrkf_restore_batch_rows_total", &[], self.batch.restore_rows);
            b.counter_add("asrkf_restore_batch_spans_total", &[], self.batch.restore_spans);
            b.counter_add("asrkf_freeze_batch_rows_total", &[], self.batch.freeze_rows);
            b.counter_add("asrkf_freeze_batch_spans_total", &[], self.batch.freeze_spans);
            b.count_merge("asrkf_restore_batch", &[], &self.batch.restore_batch);
            b.count_merge("asrkf_freeze_batch", &[], &self.batch.freeze_batch);
            b.time_merge("asrkf_plan_us", &[], &self.plan_hist);
            b.time_merge("asrkf_step_us", &[], &self.step_hist);
            b.time_merge("asrkf_step_segment_us", &[("segment", "plan")], &self.seg_plan_hist);
            b.time_merge(
                "asrkf_step_segment_us",
                &[("segment", "restore")],
                &self.seg_restore_hist,
            );
            b.time_merge(
                "asrkf_step_segment_us",
                &[("segment", "restore_wait")],
                &self.seg_wait_hist,
            );
            b.time_merge(
                "asrkf_step_segment_us",
                &[("segment", "compute")],
                &self.seg_compute_hist,
            );
            b.time_merge("asrkf_step_segment_us", &[("segment", "freeze")], &self.seg_freeze_hist);
        });
    }

    /// Per-step segment spans for the Chrome-trace decode-step track.
    pub fn step_spans(&self) -> Vec<StepSpan> {
        self.trace.iter().map(|r| r.span).collect()
    }

    /// Absorb one decode step's outputs (after the engine wrote the new
    /// KV row). Returns a recovery action for the engine to apply (RR
    /// needs KV access, so it propagates up).
    ///
    /// This is also where prefetch-ahead staging runs: the plan's
    /// imminent-thaw hints — widened to the recovery horizon when the
    /// entropy monitor trends toward a trigger — are promoted into the
    /// store's hot tier *between* decode steps, so the next
    /// `apply_plan` restores without inline dequantization. Errors are
    /// spill-tier I/O failures.
    pub fn absorb(
        &mut self,
        token: i32,
        logits: Vec<f32>,
        scores: &[f32],
        plan: &Plan,
        timing: CallTiming,
        host: Duration,
    ) -> Result<Action> {
        let a0 = now_us();
        self.mask[self.len] = 1.0;
        self.len += 1;
        self.tokens.push(token);
        self.step += 1;

        let t_observe = Instant::now();
        self.policy.observe(self.step, &scores[..self.len], self.len);
        // one sample per decode step: this step's plan + observe time
        self.plan_hist.record(self.plan_time_pending + t_observe.elapsed());
        self.plan_time_pending = Duration::ZERO;

        let entropy = logits_entropy(&logits);
        let top1 = top1_prob(&logits);
        self.last_logits = logits;

        let mut action = Action::None;
        let mut pressure = 0.0f32;
        if let (Some(mon), Some(ladder)) = (self.monitor.as_mut(), self.ladder.as_mut()) {
            let signal = mon.observe(entropy, top1);
            pressure = mon.pressure();
            action = ladder.step(self.step, signal);
            match action {
                Action::SoftReset => {
                    self.policy.request_unfreeze(UnfreezeScope::Soft);
                }
                Action::WindowReset { horizon } => {
                    self.policy
                        .request_unfreeze(UnfreezeScope::Window { n: horizon, now: self.step });
                }
                Action::FullReset => {
                    self.policy.request_unfreeze(UnfreezeScope::Full);
                }
                Action::Rewalk { .. } | Action::None => {}
            }
            if action != Action::None && !matches!(action, Action::Rewalk { .. }) {
                mon.reset();
            }
        }

        // --- prefetch-ahead staging (host-side tier moves only).
        // `prefetch_ahead` is the look-ahead in steps for both paths:
        // the policy's hints (filtered to thaws due within it) and the
        // store-driven sweep under entropy pressure.
        let ocfg = self.store.config();
        let (stage_pressure, prefetch_ahead, stage_burst) =
            (ocfg.stage_pressure, ocfg.prefetch_ahead, ocfg.stage_burst_rows);
        // dedupe hints against work already done or in progress: a row
        // staged hot, landed, or out on a speculative read gains
        // nothing from another promotion attempt
        let hints: Vec<(usize, u64)> = plan
            .prefetch
            .iter()
            .copied()
            .filter(|&(pos, eta)| {
                eta <= self.step.saturating_add(prefetch_ahead)
                    && !self.store.spec_busy(pos)
                    && !self.store.is_staged(pos)
            })
            .collect();
        let b0 = now_us();
        self.store.stage(&hints)?;
        if pressure >= stage_pressure || action != Action::None {
            // the monitor trends toward (or hit) a recovery trigger:
            // recovery unfreezes restore soonest-thaw-first, so stage a
            // broader burst ahead of them
            self.store.stage_upcoming(self.step, prefetch_ahead, stage_burst)?;
        }
        let w_stage = self.store.take_wait_us();
        let b1 = now_us();
        self.store.on_step(self.step)?;
        let w_sweep = self.store.take_wait_us();
        let c1 = now_us();
        // drive the restore pipeline at the step boundary: land
        // completed speculative reads, expire stale copies, and issue
        // the next horizon's reads to overlap with the coming step
        self.store.pipeline_advance(self.step)?;
        let w_advance = self.store.take_wait_us();

        // segment attribution: staging counts as restore work, the
        // per-step sweep as freeze work, blocked-on-landing time as
        // restore wait, and the absorb remainder (observe + monitor +
        // bookkeeping) as plan/control-plane time. The five segments
        // partition [seg_start_us, end] exactly.
        let end = now_us();
        let (start, mid) =
            if self.seg_mid_us == 0 { (a0, a0) } else { (self.seg_start_us, self.seg_mid_us) };
        // carve blocked-on-landing time out of its enclosing segment
        // (clamped to it, so the five segments still partition the
        // wall clock exactly)
        let w_stage = w_stage.min(b1 - b0);
        let w_sweep = w_sweep.min(c1 - b1);
        let plan_remainder = (end - a0) - (b1 - b0) - (c1 - b1);
        let w_advance = w_advance.min(plan_remainder);
        let span = StepSpan {
            step: self.step,
            start_us: start,
            plan_us: self.seg_plan_us + plan_remainder - w_advance,
            restore_us: self.seg_restore_us + (b1 - b0) - w_stage,
            restore_wait_us: self.seg_wait_us + w_stage + w_sweep + w_advance,
            freeze_us: self.seg_freeze_us + (c1 - b1) - w_sweep,
            compute_us: a0 - mid,
        };
        self.segments.steps += 1;
        self.segments.plan_us += span.plan_us;
        self.segments.restore_us += span.restore_us;
        self.segments.restore_wait_us += span.restore_wait_us;
        self.segments.compute_us += span.compute_us;
        self.segments.freeze_us += span.freeze_us;
        self.segments.wall_us += end - start;
        self.step_hist.record(Duration::from_micros(end - start));
        self.seg_plan_hist.record(Duration::from_micros(span.plan_us));
        self.seg_restore_hist.record(Duration::from_micros(span.restore_us));
        self.seg_wait_hist.record(Duration::from_micros(span.restore_wait_us));
        self.seg_compute_hist.record(Duration::from_micros(span.compute_us));
        self.seg_freeze_hist.record(Duration::from_micros(span.freeze_us));
        self.seg_start_us = 0;
        self.seg_mid_us = 0;
        self.seg_plan_us = 0;
        self.seg_restore_us = 0;
        self.seg_freeze_us = 0;
        self.seg_wait_us = 0;

        self.trace.push(StepRecord {
            step: self.step,
            total: self.len,
            active: self.policy.active_count(),
            frozen: self.policy.frozen_count(),
            entropy,
            froze: plan.freeze.len(),
            restored: plan.restore.len(),
            upload: timing.upload,
            execute: timing.execute,
            download: timing.download,
            host,
            recovery_level: self.ladder.as_ref().map(|l| l.level()).unwrap_or(0),
            span,
        });
        Ok(action)
    }

    /// Rewind bookkeeping for RR: truncate `back` generated tokens,
    /// reactivate every position < new len, rewind the sampler, reset
    /// the monitor. The engine has already merged frozen payloads back
    /// into the KV buffer (store is drained).
    pub fn rewind(&mut self, back: usize) {
        assert!(self.store.is_empty(), "rewind before draining the frozen store");
        let back = back.min(self.generated().saturating_sub(1));
        let new_gen = self.generated() - back;
        self.tokens.truncate(self.prompt_len + new_gen);
        let new_len = self.len - back;
        for p in 0..self.s_capacity {
            self.mask[p] = if p < new_len { 1.0 } else { 0.0 };
        }
        self.len = new_len;
        self.policy.force_all_active();
        if let Some(mon) = self.monitor.as_mut() {
            mon.reset();
        }
        // rewind the sampler stream to where token `new_gen` was drawn
        if let Some(&draws) = self.draws_at.get(new_gen) {
            self.sampler.rewind_to_draws(draws);
            self.draws_at.truncate(new_gen);
        }
    }

    pub fn active_kv(&self) -> usize {
        self.policy.active_count()
    }
}
