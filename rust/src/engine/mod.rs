//! Generation engines: KV layout helpers, per-sequence session state,
//! and the single-sequence generator. The batched serving path is in
//! `crate::coordinator`.

pub mod generator;
pub mod layout;
pub mod session;

pub use generator::{GenOutcome, GenStats, Generator};
pub use layout::KvGeom;
pub use session::{Session, StepRecord};
