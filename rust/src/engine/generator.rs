//! Single-sequence generation engine: prefill -> rolling decode loop
//! with the freeze policy in charge of the active set each step.
//! This is the engine behind Table 1, Figure 1, Tables 2-3 and the
//! quickstart example; the batched serving engine lives in
//! `crate::coordinator`.

use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::config::EngineConfig;
use crate::engine::layout::{insert_prefill, scatter_row, KvGeom};
use crate::engine::session::{Session, StepRecord};
use crate::error::{Error, Result};
use crate::kv::policy::KvPolicy;
use crate::model::tokenizer;
use crate::recovery::Action;
use crate::runtime::{DecodeInputs, DecodeProgram, Runtime};

/// Aggregate statistics for one generation run.
#[derive(Debug, Clone)]
pub struct GenStats {
    pub total_tokens: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub final_active_kv: usize,
    pub mean_active_kv: f64,
    pub peak_active_kv: usize,
    /// 1 - final_active / total (the paper's Table 1/3 metric)
    pub compression: f64,
    pub freezes: u64,
    pub restores: u64,
    pub recovery_interventions: usize,
    /// interventions by ladder level [SR, WR, FR, RR]
    pub recovery_by_level: [usize; 4],
    pub wall: Duration,
    pub upload: Duration,
    pub execute: Duration,
    pub download: Duration,
    pub host: Duration,
    /// Tiered-store snapshot at end of generation: per-tier occupancy,
    /// staged-hit counters, restore latencies (see `crate::offload`).
    pub offload: crate::offload::OffloadSummary,
    /// Per-step policy control-plane time (`plan` + `observe`) — the
    /// indexed policy's O(work)-per-step contract, measured.
    pub plan_latency: crate::metrics::PlanLatency,
    /// Cumulative decode-step wall-clock split into
    /// plan/restore/compute/freeze segments (sums to the measured step
    /// wall-clock by construction).
    pub segments: crate::metrics::StepSegments,
}

/// Final disposition of one KV row (mechanism-level retrieval probe,
/// Table 2): a row is *recoverable* iff its data is either in the
/// active cache or stashed in the frozen store. Irreversible baselines
/// leave `Lost` rows — exactly the failure the paper's soft freeze
/// removes (§3.3 "no permanent information loss").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowState {
    Active,
    /// frozen, payload stashed — restorable on demand
    Recoverable,
    /// evicted, payload dropped — gone forever
    Lost,
}

pub struct GenOutcome {
    pub text: String,
    pub tokens: Vec<i32>,
    pub trace: Vec<StepRecord>,
    pub stats: GenStats,
    /// per-position row disposition at end of generation (len entries)
    pub row_states: Vec<RowState>,
    /// merged flight-recorder timeline (`(shard, event)` pairs, capture
    /// order) — feeds the `--trace-out` Chrome trace
    pub flight: Vec<(usize, crate::metrics::FlightEvent)>,
    /// per-step segment spans for the trace's decode-step track
    pub step_spans: Vec<crate::metrics::StepSpan>,
}

pub struct Generator<'rt> {
    rt: &'rt Runtime,
    cfg: EngineConfig,
}

impl<'rt> Generator<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Self {
        Generator { rt, cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Generate `max_new` tokens from `prompt` under `policy`.
    pub fn generate(
        &self,
        prompt: &str,
        policy: Box<dyn KvPolicy>,
        max_new: usize,
    ) -> Result<GenOutcome> {
        self.generate_with_resume(prompt, policy, max_new, false)
    }

    /// Like [`Generator::generate`], optionally resuming from a
    /// persistent spill directory (`--spill-persist --resume-spill`):
    /// the session re-attaches instead of reclaiming a crashed
    /// process's records, and the recovered-row counters ride along on
    /// `GenStats::offload`.
    pub fn generate_with_resume(
        &self,
        prompt: &str,
        policy: Box<dyn KvPolicy>,
        max_new: usize,
        resume_spill: bool,
    ) -> Result<GenOutcome> {
        let t_start = Instant::now();
        let model = self.rt.manifest.model.clone();
        let prompt_tokens = tokenizer::encode(prompt);
        if prompt_tokens.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }

        // --- bucket selection
        let need = prompt_tokens.len() + max_new;
        let decode: Rc<DecodeProgram> = self.rt.decode_for(1, need)?;
        let s = decode.kv_len;
        // per-step transfer budget: engine config, capped by the
        // manifest's advisory value
        let r = self.cfg.freeze.r_budget.min(decode.r_budget.max(1));
        let geom = KvGeom::new(&model, 1, s);

        // --- prefill
        let prefill = self.rt.prefill_for(prompt_tokens.len())?;
        let l = prefill.len;
        let mut padded = prompt_tokens.clone();
        padded.resize(l, b' ' as i32);
        let pf = prefill.run(&padded, &[prompt_tokens.len() as i32])?;

        let mut kv = vec![0.0f32; geom.floats()];
        insert_prefill(&mut kv, &geom, 0, &pf.kv, l, prompt_tokens.len());

        let rf = model.kv_row_floats;
        let mut session = if resume_spill {
            Session::resume(0, prompt_tokens.clone(), max_new, policy, &self.cfg, s, rf)?
        } else {
            Session::new(0, prompt_tokens.clone(), max_new, policy, &self.cfg, s, rf)?
        };
        session.seed_prefill(pf.logits_last, &pf.scores_last, prompt_tokens.len());

        let mut upload = pf.timing.upload;
        let mut execute = pf.timing.execute;
        let mut download = pf.timing.download;
        let mut host = Duration::ZERO;

        // --- rolling decode loop (paper Algorithm 1)
        // one plan buffer for the whole generation: plan_into refills
        // it in place, so steady-state steps allocate nothing for plans
        let mut plan = crate::kv::Plan::default();
        while !session.is_done() {
            let t_host = Instant::now();
            let token = session.next_token();
            // freeze/restore data movement on the host-owned cache;
            // restores hit staged hot rows when prefetch ran ahead
            session.apply_plan(&mut kv, &geom, 0, r, &mut plan)?;
            let host_pre = t_host.elapsed();

            let inputs = DecodeInputs {
                tokens: &[token],
                kv: &kv,
                mask: &session.mask,
                pos: &[session.len as i32],
            };
            let out = decode.run(&inputs)?;

            let t_host2 = Instant::now();
            // the graph is pure: rust writes the new KV row itself
            crate::engine::layout::write_new_row(
                &mut kv, &geom, 0, session.len, &out.k_new, &out.v_new,
            );
            let action =
                session.absorb(token, out.logits, &out.scores, &plan, out.timing, host_pre)?;
            let host_post = t_host2.elapsed();

            upload += out.timing.upload;
            execute += out.timing.execute;
            download += out.timing.download;
            host += host_pre + host_post;

            if let Action::Rewalk { depth } = action {
                self.apply_rewalk(&mut session, &mut kv, &geom, &decode, depth)?;
            }
        }

        // land any speculative restores still in flight so the final
        // counters, gauges, and flight timeline below are complete
        session.store.settle()?;

        let trace = session.trace.clone();
        let (mut sum_active, mut peak) = (0u64, 0usize);
        for t in &trace {
            sum_active += t.active as u64;
            peak = peak.max(t.active);
        }
        let total = session.len;
        let final_active = session.active_kv();
        let stats = GenStats {
            total_tokens: total,
            prompt_tokens: session.prompt_len,
            generated_tokens: session.generated(),
            final_active_kv: final_active,
            mean_active_kv: if trace.is_empty() {
                total as f64
            } else {
                sum_active as f64 / trace.len() as f64
            },
            peak_active_kv: peak,
            compression: 1.0 - final_active as f64 / total.max(1) as f64,
            freezes: session.store.total_stashed() + session.store.total_dropped(),
            restores: session.store.total_restored(),
            recovery_interventions: session
                .ladder
                .as_ref()
                .map(|l| l.interventions.len())
                .unwrap_or(0),
            recovery_by_level: session
                .ladder
                .as_ref()
                .map(|l| {
                    let mut by = [0usize; 4];
                    for (_, a) in &l.interventions {
                        match a {
                            Action::SoftReset => by[0] += 1,
                            Action::WindowReset { .. } => by[1] += 1,
                            Action::FullReset => by[2] += 1,
                            Action::Rewalk { .. } => by[3] += 1,
                            Action::None => {}
                        }
                    }
                    by
                })
                .unwrap_or_default(),
            wall: t_start.elapsed(),
            upload,
            execute,
            download,
            host,
            offload: session.offload_summary(),
            plan_latency: session.plan_latency(),
            segments: session.segments,
        };
        // fold this run into the process-wide registry: monotone flows
        // via the session, plus the final occupancy gauges (the single-
        // session path owns the only live store, so gauges can't
        // collide with another publisher)
        let reg = crate::metrics::Registry::global();
        session.publish_to_registry(reg);
        reg.publish(|b| {
            session.store.publish_gauges(b);
            b.counter_add("asrkf_tokens_generated_total", &[], session.generated() as u64);
            b.counter_add("asrkf_prefill_tokens_total", &[], session.prompt_len as u64);
            b.counter_add("asrkf_requests_completed_total", &[], 1);
        });
        let row_states = (0..session.len)
            .map(|pos| {
                if !session.policy.is_frozen(pos) {
                    RowState::Active
                } else if session.store.contains(pos) {
                    RowState::Recoverable
                } else {
                    RowState::Lost
                }
            })
            .collect();
        Ok(GenOutcome {
            text: session.generated_text(),
            tokens: session.tokens.clone(),
            trace,
            stats,
            row_states,
            flight: session.store.flight_events(),
            step_spans: session.step_spans(),
        })
    }

    /// RR recovery: merge every frozen payload back into the cache
    /// (CPU-storage -> active), rewind `depth` generated tokens, and
    /// recompute the logits at the new frontier by re-running the last
    /// surviving token through the decode graph.
    fn apply_rewalk(
        &self,
        session: &mut Session,
        kv: &mut [f32],
        geom: &KvGeom,
        decode: &DecodeProgram,
        depth: usize,
    ) -> Result<()> {
        log::warn!(
            "RR recovery: rewinding {depth} tokens at step {} (len {})",
            session.step,
            session.len
        );
        // single-row scatter on purpose: this is the emergency path
        // (drain order is arbitrary, batching buys nothing here); the
        // per-step plan path goes through the batched `scatter_rows`.
        for (pos, row) in session.store.drain_all()? {
            scatter_row(kv, geom, 0, pos, &row);
        }
        session.rewind(depth);

        // recompute frontier logits: re-run the last surviving token at
        // its own position. The pure decode graph folds the "current"
        // token separately from the cache, so its cache row must be
        // masked out for this call (it is already written).
        let last = *session.tokens.last().expect("rewind kept >= 1 token");
        let mut mask = session.mask.clone();
        mask[session.len - 1] = 0.0;
        let out = decode.run(&DecodeInputs {
            tokens: &[last],
            kv,
            mask: &mask,
            pos: &[(session.len - 1) as i32],
        })?;
        crate::engine::layout::write_new_row(
            kv, geom, 0, session.len - 1, &out.k_new, &out.v_new,
        );
        session.last_logits = out.logits;
        Ok(())
    }
}
