"""AOT export: train (cached) -> lower every program variant -> HLO text.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Trained parameters are closed over and therefore baked into the HLO as
constants — the rust binary feeds only dynamic state (tokens, KV, masks,
freeze/restore row transfers) and is fully self-contained at runtime.

Usage:  python -m compile.aot --out-dir ../artifacts [--retrain]
Env:    ASRKF_TRAIN_STEPS=N   override training steps (CI smoke: 60)
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import DEFAULT_EXPORT, DEFAULT_MODEL, DEFAULT_TRAIN, TrainConfig, manifest_dict
from .model import decode_step, prefill_apply
from .train import load_params, save_params, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big literals as `{...}`, silently dropping the baked model
    # weights from the interchange text.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_prefill(params, cfg, b, l):
    def fn(tokens, length):
        return prefill_apply(params, cfg, tokens, length)

    return jax.jit(fn).lower(_spec((b, l), jnp.int32), _spec((b,), jnp.int32))


def lower_decode(params, cfg, b, s, block_k):
    """Lower the pure decode step: (token, kv, mask, pos) ->
    (logits, k_new, v_new, scores). All cache mutations (row write,
    freeze/restore movement) are host-side rust operations."""
    nl, h, d = cfg.n_layers, cfg.n_heads, cfg.d_head

    def fn(token, kv, mask, pos):
        return decode_step(params, cfg, token, kv, mask, pos, block_k=block_k)

    return jax.jit(fn).lower(
        _spec((b,), jnp.int32),
        _spec((nl, 2, b, s, h, d)),
        _spec((b, s)),
        _spec((b,), jnp.int32),
    )


def get_params(out_dir: str, retrain: bool):
    cfg, tc = DEFAULT_MODEL, DEFAULT_TRAIN
    steps_env = os.environ.get("ASRKF_TRAIN_STEPS")
    if steps_env:
        tc = TrainConfig(steps=int(steps_env), warmup=min(tc.warmup, int(steps_env) // 4 + 1))
    params_path = os.path.join(out_dir, "params.npz")
    if os.path.exists(params_path) and not retrain:
        print(f"[aot] loading cached params from {params_path}")
        return load_params(params_path, cfg)
    print(f"[aot] training stand-in model: {tc.steps} steps")
    params, _ = train(cfg, tc, log_path=os.path.join(out_dir, "train_log.json"))
    save_params(params, params_path)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg, ex = DEFAULT_MODEL, DEFAULT_EXPORT
    params = get_params(args.out_dir, args.retrain)

    manifest = manifest_dict(cfg, ex)
    manifest["programs"] = {}

    jobs = []
    for (b, l) in ex.prefill_buckets:
        jobs.append((f"prefill_b{b}_l{l}", lambda b=b, l=l: lower_prefill(params, cfg, b, l),
                     {"kind": "prefill", "batch": b, "len": l}))
    for (b, s) in ex.decode_buckets:
        jobs.append((f"decode_b{b}_s{s}",
                     lambda b=b, s=s: lower_decode(params, cfg, b, s, ex.block_k),
                     {"kind": "decode", "batch": b, "kv_len": s, "r_budget": ex.r_budget}))

    for name, lower, meta in jobs:
        t0 = time.time()
        text = to_hlo_text(lower())
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        meta["bytes"] = len(text)
        manifest["programs"][name] = meta
        print(f"[aot] {name}: {len(text)/1e6:.1f} MB HLO text ({time.time()-t0:.1f}s)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(jobs)} programs to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
