"""Layer-2 JAX model: ByteGPT decoder with an externally-managed KV cache.

Three entry points, all lowered to HLO text by `aot.py` with trained
parameters baked in as constants:

  * `prefill_apply`  — full-prompt forward; returns last-position logits,
    the KV rows for the whole prompt, and the last query's Eq.2 relevance
    scores (the freeze scheduler's initial signal).
  * `decode_apply`   — one generation step over the rust-owned KV cache.
    Besides the usual (token, kv, mask, pos) it takes *freeze/restore row
    transfers*: the graph scatters restored rows back into the cache,
    gathers rows being frozen (returning them for the host to stash) and
    zeroes them on-"device", making the paper's soft freeze a real data
    movement rather than a flag (DESIGN.md §1).
  * `train_forward`  — plain causal forward used only by train.py.

Array layouts:
  kv          [nl, 2, B, S, H, D]   (axis 1: 0=K, 1=V; RoPE applied to K)
  row bundle  [R, nl, 2, H, D]      one token's KV across layers
  pad index   S (one past the end)  for unused freeze/restore slots
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.fused import fused_decode_attention, fused_decode_attention_parts

BIG = 1e30


# ---------------------------------------------------------------------------
# Parameters


def init_params(rng, cfg: ModelConfig) -> dict:
    """Initialise parameters (scaled-normal, tied embedding/unembedding)."""
    keys = jax.random.split(rng, 2 + cfg.n_layers)

    def dense(key, n_in, n_out):
        return jax.random.normal(key, (n_in, n_out), jnp.float32) * (n_in ** -0.5)

    d, h, dh, f = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "layers": [],
    }
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 7)
        params["layers"].append({
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "wq": dense(ks[0], d, h * dh),
            "wk": dense(ks[1], d, h * dh),
            "wv": dense(ks[2], d, h * dh),
            "wo": dense(ks[3], h * dh, d),
            "w_gate": dense(ks[4], d, f),
            "w_up": dense(ks[5], d, f),
            "w_down": dense(ks[6], f, d),
        })
    return params


# ---------------------------------------------------------------------------
# Building blocks


def _layer_norm(x, p):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]


def _rope_angles(pos, dh, theta):
    """pos [...], returns (cos, sin) of shape [..., dh//2]."""
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, pos, theta):
    """Rotary embedding. x [..., H, D], pos broadcastable to x[..., ] batch dims."""
    dh = x.shape[-1]
    cos, sin = _rope_angles(pos, dh, theta)          # [..., dh//2]
    cos, sin = cos[..., None, :], sin[..., None, :]  # add head axis
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _swiglu(x, p):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def _qkv(x, p, cfg):
    """x [..., d] -> q, k, v [..., H, D]."""
    def split(w):
        y = x @ w
        return y.reshape(y.shape[:-1] + (cfg.n_heads, cfg.d_head))
    return split(p["wq"]), split(p["wk"]), split(p["wv"])


# ---------------------------------------------------------------------------
# Row scatter/gather helpers (freeze/restore data movement)


def _scatter_rows_one(kv_b, idx, rows):
    """kv_b [nl,2,S,H,D]; idx [R] (pad=S drops); rows [R,nl,2,H,D]."""
    rows_t = jnp.moveaxis(rows, 0, 2)  # [nl,2,R,H,D]
    return kv_b.at[:, :, idx].set(rows_t, mode="drop")


def _gather_rows_one(kv_b, idx):
    """kv_b [nl,2,S,H,D]; idx [R] -> [R,nl,2,H,D] (pad slots = 0)."""
    rows = jnp.take(kv_b, idx, axis=2, mode="fill", fill_value=0.0)  # [nl,2,R,H,D]
    return jnp.moveaxis(rows, 2, 0)


def _zero_rows_one(kv_b, idx):
    zeros = jnp.zeros((kv_b.shape[0], kv_b.shape[1], idx.shape[0]) + kv_b.shape[3:], kv_b.dtype)
    return kv_b.at[:, :, idx].set(zeros, mode="drop")


def _write_row_one(cache_b, pos, row):
    """cache_b [S,H,D]; write row [H,D] at pos (scalar)."""
    return cache_b.at[pos].set(row)


_scatter_rows = jax.vmap(_scatter_rows_one, in_axes=(2, 0, 0), out_axes=2)
_gather_rows = jax.vmap(_gather_rows_one, in_axes=(2, 0), out_axes=0)
_zero_rows = jax.vmap(_zero_rows_one, in_axes=(2, 0), out_axes=2)
_write_row = jax.vmap(_write_row_one, in_axes=(0, 0, 0), out_axes=0)


# ---------------------------------------------------------------------------
# Decode step (hot path): PURE function of the cache.
#
# The cache is a read-only input — no in-graph scatter/gather/update.
# The rust engine owns every state mutation (writing the new row,
# freeze/restore data movement); this removes all full-cache copies
# from the step graph (§Perf: the original stateful variant spent most
# of its time in dynamic-update-slice materializations).


def decode_step(params, cfg: ModelConfig, token, kv, mask, pos, *, block_k=64):
    """One generation step over a read-only KV cache.

    Args:
      token [B] i32 — token sampled at the previous step (its KV row is
          NOT yet in the cache; it is computed here and folded into the
          attention in-kernel state before normalization).
      kv    [nl,2,B,S,H,D] f32 — cache. Frozen rows are zeroed and
          masked; the row at `pos` is ignored (mask 0).
      mask  [B,S] f32 — activity mask (current position NOT set).
      pos   [B] i32 — position of `token` (for RoPE).

    Returns:
      logits [B,V], k_new [nl,B,H,D], v_new [nl,B,H,D], scores [B,S].
      The engine writes k_new/v_new into its cache at `pos` after the
      call; Eq.2 scores cover cache rows (zero on frozen/invalid).
    """
    b = token.shape[0]
    x = params["embed"][token]                      # [B, d]
    scores_acc = jnp.zeros_like(mask)
    k_rows, v_rows = [], []
    for li, lp in enumerate(params["layers"]):
        h_in = _layer_norm(x, lp["ln1"])
        q, k_new, v_new = _qkv(h_in, lp, cfg)       # [B,H,D] each
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
        k_rows.append(k_new)
        v_rows.append(v_new)

        acc, m, l, scores = fused_decode_attention_parts(
            q, kv[li, 0], kv[li, 1], mask, block_k=block_k)
        # fold the current token's row into the running softmax
        scale = cfg.d_head ** -0.5
        s_new = jnp.einsum("bhd,bhd->bh", q, k_new) * scale   # [B,H]
        m2 = jnp.maximum(m, s_new)
        alpha = jnp.exp(m - m2)
        p_new = jnp.exp(s_new - m2)
        l2 = l * alpha + p_new
        attn = (acc * alpha[..., None] + p_new[..., None] * v_new) / l2[..., None]

        scores_acc = scores_acc + scores
        x = x + attn.reshape(b, -1) @ lp["wo"]
        x = x + _swiglu(_layer_norm(x, lp["ln2"]), lp)

    x = _layer_norm(x, params["ln_f"])
    logits = x @ params["embed"].T
    return logits, jnp.stack(k_rows), jnp.stack(v_rows), scores_acc / cfg.n_layers


# ---------------------------------------------------------------------------
# Decode step (stateful reference variant, kept for tests/ablation):
# performs restore-scatter, freeze-gather+zero and the row write inside
# the graph. The AOT export uses `decode_step` above.


def decode_apply(params, cfg: ModelConfig, token, kv, mask, pos,
                 restore_idx, restore_rows, freeze_idx, *, block_k=64):
    """One generation step.

    Args:
      token        [B] i32 — token sampled at the previous step.
      kv           [nl,2,B,S,H,D] f32 — cache (authoritative copy may be
                   host- or device-resident; the graph is agnostic).
      mask         [B,S] f32 — activity mask for THIS step: restored rows
                   already 1, rows frozen this step already 0. The graph
                   itself activates the current position.
      pos          [B] i32 — write position of `token`'s KV row.
      restore_idx  [B,R] i32 — rows to scatter back (pad = S).
      restore_rows [B,R,nl,2,H,D] f32 — their stashed contents.
      freeze_idx   [B,R] i32 — rows to gather + zero (pad = S).

    Returns:
      logits       [B,V] f32
      kv_out       [nl,2,B,S,H,D] f32 — updated cache.
      scores       [B,S] f32 — Eq.2 relevance, averaged over layers.
      frozen_rows  [B,R,nl,2,H,D] f32 — contents of rows frozen this step
                   (payload for the host-side frozen store).
    """
    # 1. restore previously-frozen rows, then extract + zero freshly-frozen ones
    kv = _scatter_rows(kv, restore_idx, restore_rows)
    frozen_rows = _gather_rows(kv, freeze_idx)
    kv = _zero_rows(kv, freeze_idx)

    # 2. activate current position in the attention mask
    b = token.shape[0]
    mask = _write_row(mask[..., None], pos, jnp.ones((b, 1)))[..., 0]

    x = params["embed"][token]                      # [B, d]
    scores_acc = jnp.zeros_like(mask)
    for li, lp in enumerate(params["layers"]):
        h_in = _layer_norm(x, lp["ln1"])
        q, k_new, v_new = _qkv(h_in, lp, cfg)       # [B,H,D] each
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

        k_cache = _write_row(kv[li, 0], pos, k_new)  # [B,S,H,D]
        v_cache = _write_row(kv[li, 1], pos, v_new)
        kv = kv.at[li, 0].set(k_cache).at[li, 1].set(v_cache)

        attn, scores = fused_decode_attention(q, k_cache, v_cache, mask, block_k=block_k)
        scores_acc = scores_acc + scores
        x = x + attn.reshape(b, -1) @ lp["wo"]
        x = x + _swiglu(_layer_norm(x, lp["ln2"]), lp)

    x = _layer_norm(x, params["ln_f"])
    logits = x @ params["embed"].T
    return logits, kv, scores_acc / cfg.n_layers, frozen_rows


# ---------------------------------------------------------------------------
# Prefill


def prefill_apply(params, cfg: ModelConfig, tokens, length):
    """Full-prompt forward with causal attention.

    Args:
      tokens [B,L] i32 (right-padded), length [B] i32 valid lengths.
    Returns:
      logits_last [B,V] — logits at position length-1.
      kv          [nl,2,B,L,H,D] — RoPE'd KV rows for the prompt.
      scores_last [B,L] — Eq.2 relevance of the final query vs the prompt.
    """
    b, l = tokens.shape
    pos = jnp.arange(l)
    valid = (pos[None, :] < length[:, None])                       # [B,L]
    causal = pos[None, :] <= pos[:, None]                          # [L,L]
    attn_mask = causal[None] & valid[:, None, :]                   # [B,L,L]

    x = params["embed"][tokens]                                    # [B,L,d]
    kv_rows = []
    scores_last = jnp.zeros((b, l))
    scale = cfg.d_head ** -0.5
    for lp in params["layers"]:
        h_in = _layer_norm(x, lp["ln1"])
        q, k, v = _qkv(h_in, lp, cfg)                              # [B,L,H,D]
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)
        kv_rows.append(jnp.stack([k, v]))                          # [2,B,L,H,D]

        logits = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
        logits = jnp.where(attn_mask[:, None], logits, -BIG)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhij,bjhd->bihd", w, v)
        x = x + attn.reshape(b, l, -1) @ lp["wo"]
        x = x + _swiglu(_layer_norm(x, lp["ln2"]), lp)

        # Eq.2 relevance of the last valid query against every position
        q_last = jnp.take_along_axis(
            q, (length - 1)[:, None, None, None].astype(jnp.int32), axis=1
        )[:, 0]                                                    # [B,H,D]
        s = jnp.abs(jnp.einsum("bhd,bjhd->bjh", q_last, k)).mean(-1)
        scores_last = scores_last + s * valid

    x = _layer_norm(x, params["ln_f"])
    logits_all = x @ params["embed"].T                             # [B,L,V]
    logits_last = jnp.take_along_axis(
        logits_all, (length - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]
    return logits_last, jnp.stack(kv_rows), scores_last / cfg.n_layers


# ---------------------------------------------------------------------------
# Training forward (build-time only)


def train_forward(params, cfg: ModelConfig, tokens):
    """Causal LM forward for training: tokens [B,L] -> logits [B,L,V]."""
    b, l = tokens.shape
    pos = jnp.arange(l)
    causal = pos[None, :] <= pos[:, None]
    x = params["embed"][tokens]
    scale = cfg.d_head ** -0.5
    for lp in params["layers"]:
        h_in = _layer_norm(x, lp["ln1"])
        q, k, v = _qkv(h_in, lp, cfg)
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)
        logits = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
        logits = jnp.where(causal[None, None], logits, -BIG)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhij,bjhd->bihd", w, v)
        x = x + attn.reshape(b, l, -1) @ lp["wo"]
        x = x + _swiglu(_layer_norm(x, lp["ln2"]), lp)
    x = _layer_norm(x, params["ln_f"])
    return x @ params["embed"].T
