"""Synthetic byte-level training corpus + passkey curriculum.

The paper evaluates on LLaMA-3 8B with natural-language prompts and a
passkey-retrieval needle test. We have no model weights or corpus in
this environment (repro band 0), so we build the closest synthetic
equivalent (DESIGN.md §3): a template-generated English-like corpus the
ByteGPT stand-in can actually learn, plus a copy curriculum that makes
passkey retrieval a learnable skill — which is exactly what the
needle-in-haystack experiment (Table 2) needs to be meaningful.

Everything is deterministic given a seed; raw bytes are the vocabulary.
"""

import numpy as np

SUBJECTS = [
    "the model", "the system", "the cache", "a token", "the scheduler",
    "the server", "a request", "the window", "the kernel", "the router",
    "the engine", "a batch", "the queue", "memory", "the process",
    "the network", "a signal", "the buffer", "an index", "the store",
]
VERBS = [
    "updates", "freezes", "restores", "computes", "routes", "stores",
    "evicts", "scans", "emits", "tracks", "samples", "decodes",
    "encodes", "schedules", "balances", "monitors", "rewrites", "reads",
]
OBJECTS = [
    "the key value pairs", "the attention scores", "a sliding window",
    "the frozen rows", "the active cache", "every request", "the logits",
    "the relevance signal", "a freeze timer", "the entropy trace",
    "the next token", "the decode step", "the batch queue",
    "the memory budget", "the recovery ladder", "the context",
]
ADVERBS = [
    "quickly", "slowly", "carefully", "eagerly", "lazily", "often",
    "rarely", "smoothly", "safely", "twice", "in order", "at once",
]
CONNECTIVES = ["then", "meanwhile", "however", "therefore", "later", "next"]

FILLER_SENTENCES = [
    "the grass is green and the sky is blue here. ",
    "one two three four five six seven eight nine ten. ",
    "the quick brown fox jumps over the lazy dog again. ",
    "rain falls on the hills and rivers run to the sea. ",
    "day follows night and night follows day as always. ",
]

PASSKEY_PREFIX = b"the pass key is "
PASSKEY_QUERY = b"what is the pass key? the pass key is "


def sentence(rng: np.random.Generator) -> str:
    s = f"{rng.choice(SUBJECTS)} {rng.choice(VERBS)} {rng.choice(OBJECTS)}"
    if rng.random() < 0.4:
        s += f" {rng.choice(ADVERBS)}"
    if rng.random() < 0.3:
        s += f" {rng.choice(CONNECTIVES)} {rng.choice(SUBJECTS)} {rng.choice(VERBS)} {rng.choice(OBJECTS)}"
    return s + ". "


def prose(rng: np.random.Generator, n_bytes: int) -> bytes:
    out = []
    total = 0
    while total < n_bytes:
        s = sentence(rng).encode()
        out.append(s)
        total += len(s)
    return b"".join(out)[:n_bytes]


def filler(rng: np.random.Generator, n_bytes: int) -> bytes:
    """Repetitive low-information filler, like the paper's haystack text."""
    out = []
    total = 0
    while total < n_bytes:
        s = FILLER_SENTENCES[int(rng.integers(len(FILLER_SENTENCES)))].encode()
        out.append(s)
        total += len(s)
    return b"".join(out)[:n_bytes]


def passkey_sample(rng: np.random.Generator, seq_len: int, key: str | None = None) -> bytes:
    """`the pass key is NNNNN. <filler> what is the pass key? the pass key is NNNNN.`"""
    if key is None:
        key = f"{rng.integers(10000, 100000)}"
    head = PASSKEY_PREFIX + key.encode() + b". remember it. "
    tail = PASSKEY_QUERY + key.encode() + b". "
    fill_len = max(0, seq_len - len(head) - len(tail))
    return (head + filler(rng, fill_len) + tail)[:seq_len]


def make_passkey_prompt(rng: np.random.Generator, total_len: int, key: str) -> bytes:
    """Evaluation prompt: needle + filler + query, WITHOUT the answer."""
    head = PASSKEY_PREFIX + key.encode() + b". remember it. "
    tail = PASSKEY_QUERY
    fill_len = max(0, total_len - len(head) - len(tail))
    return head + filler(rng, fill_len) + tail


def batch_iterator(seed: int, batch: int, seq_len: int, passkey_frac: float):
    """Yields [batch, seq_len] uint8 arrays forever (deterministic)."""
    rng = np.random.default_rng(seed)
    while True:
        rows = []
        for _ in range(batch):
            if rng.random() < passkey_frac:
                # vary needle distance so retrieval generalises across lengths
                sub_len = int(rng.integers(seq_len // 4, seq_len + 1))
                sample = passkey_sample(rng, sub_len)
                sample = prose(rng, seq_len - len(sample)) + sample
            else:
                sample = prose(rng, seq_len)
            rows.append(np.frombuffer(sample[:seq_len].ljust(seq_len, b" "), dtype=np.uint8))
        yield np.stack(rows)
