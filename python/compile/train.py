"""Build-time training of the ByteGPT stand-in model.

Hand-rolled AdamW + cosine schedule (optax is not available in this
environment). Runs once under `make artifacts`; parameters are cached in
`artifacts/params.npz` and baked into the exported HLO as constants.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, TrainConfig
from .data import batch_iterator
from .model import init_params, train_forward


def _tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def loss_fn(params, cfg, tokens):
    """Next-byte cross entropy, digit targets upweighted.

    The passkey-retrieval skill (Table 2) hinges on ~5 digit bytes per
    curriculum sample — ~2% of positions. Without upweighting the model
    converges on the templated prose long before induction-copying of
    the key emerges; 16x weight on digit targets fixes the signal ratio
    (digits barely occur outside passkeys in this corpus).
    """
    logits = train_forward(params, cfg, tokens[:, :-1].astype(jnp.int32))
    targets = tokens[:, 1:].astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    is_digit = (targets >= 48) & (targets <= 57)
    w = jnp.where(is_digit, 16.0, 1.0)
    return (nll * w).sum() / w.sum()


def make_update_step(cfg: ModelConfig, tc: TrainConfig):
    def schedule(step):
        warm = jnp.minimum(1.0, step / tc.warmup)
        progress = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0.0, 1.0)
        return tc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))

    @jax.jit
    def update(params, m, v, step, tokens):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens)
        lr = schedule(step)
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        t = step + 1
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)
        params = jax.tree.map(
            lambda p, mi, vi: p
            - lr * (mi * mhat_scale / (jnp.sqrt(vi * vhat_scale) + eps) + tc.weight_decay * p),
            params, m, v,
        )
        return params, m, v, loss

    return update


def train(cfg: ModelConfig, tc: TrainConfig, log_path: str | None = None, init: dict | None = None):
    """Train (from scratch or continuing from `init`); returns (params, loss_log)."""
    rng = jax.random.PRNGKey(tc.seed)
    params = init if init is not None else init_params(rng, cfg)
    m, v = _tree_zeros_like(params), _tree_zeros_like(params)
    update = make_update_step(cfg, tc)
    data = batch_iterator(tc.seed, tc.batch, tc.seq_len + 1, tc.passkey_frac)

    log = []
    t0 = time.time()
    for step in range(tc.steps):
        tokens = jnp.asarray(next(data))
        params, m, v, loss = update(params, m, v, jnp.asarray(step, jnp.float32), tokens)
        if step % 50 == 0 or step == tc.steps - 1:
            log.append({"step": step, "loss": float(loss), "elapsed_s": round(time.time() - t0, 1)})
            print(f"[train] step {step:5d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    if log_path:
        with open(log_path, "w") as f:
            json.dump(log, f, indent=1)
    return params, log


def save_params(params, path: str):
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        flat[key] = np.asarray(leaf)
    np.savez(path, **flat)


def load_params(path: str, cfg: ModelConfig):
    """Load params saved by save_params, reconstructing the pytree layout."""
    data = np.load(path)
    template = init_params(jax.random.PRNGKey(0), cfg)
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in leaves_kp:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        arr = jnp.asarray(data[key])
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
