"""Model / export configuration shared by the whole compile path.

Single source of truth for dimensions; `aot.py` serializes this into
`artifacts/manifest.json`, which `rust/src/runtime/artifacts.rs` reads.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """ByteGPT decoder dimensions (the LLaMA-3-8B stand-in, see DESIGN.md §3)."""

    vocab: int = 256          # raw byte vocabulary
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 384           # SwiGLU inner width
    max_len: int = 2048       # decode-time KV capacity (S)
    rope_theta: float = 10000.0

    @property
    def kv_row_floats(self) -> int:
        """Floats per token KV row across all layers (K and V)."""
        return self.n_layers * 2 * self.n_heads * self.d_head


@dataclass(frozen=True)
class ExportConfig:
    """Which program variants `aot.py` lowers to HLO text."""

    # prefill buckets: (batch, padded prompt length)
    prefill_buckets: Tuple[Tuple[int, int], ...] = ((1, 128), (1, 512), (1, 1024), (1, 2048))
    # decode buckets: (batch, KV capacity S)
    decode_buckets: Tuple[Tuple[int, int], ...] = ((1, 1024), (1, 2048), (4, 1024), (8, 512))
    # advisory per-step freeze/restore transfer budget (engine-side config;
    # recorded in the manifest for the rust default)
    r_budget: int = 64
    # pallas KV tile rows
    block_k: int = 64


@dataclass(frozen=True)
class TrainConfig:
    """Build-time training of the stand-in model (python/compile/train.py)."""

    # sized for the single-core CPU build environment (DESIGN.md §3):
    # templated byte corpus is low-entropy, so a short run converges
    seq_len: int = 256
    batch: int = 8
    steps: int = 1800
    lr: float = 3e-3
    warmup: int = 50
    weight_decay: float = 0.01
    seed: int = 1234
    # fraction of training sequences that are passkey copy-curriculum samples
    passkey_frac: float = 0.55


DEFAULT_MODEL = ModelConfig()
DEFAULT_EXPORT = ExportConfig()
DEFAULT_TRAIN = TrainConfig()


def manifest_dict(model: ModelConfig, export: ExportConfig) -> dict:
    d = asdict(model)
    d["kv_row_floats"] = model.kv_row_floats
    return {
        "model": d,
        "export": {
            "prefill_buckets": [list(b) for b in export.prefill_buckets],
            "decode_buckets": [list(b) for b in export.decode_buckets],
            "r_budget": export.r_budget,
            "block_k": export.block_k,
        },
    }
