"""Standalone freeze-masked flash-decode attention kernel (no relevance).

The unfused variant of `fused.py` — used by tests to isolate the
attention math, and by the L2 ablation comparing fused vs unfused HLO
(DESIGN.md §Perf: the fused kernel makes one pass over KV, the unfused
pair makes two).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BIG = 1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *, scale, n_blocks):
    sb = pl.program_id(1)
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    mask = mask_ref[0]

    qk = jnp.einsum("hd,jhd->hj", q, k, preferred_element_type=jnp.float32)

    @pl.when(sb == 0)
    def _init():
        m_ref[0, :] = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
        l_ref[0, :] = jnp.zeros((q.shape[0],), jnp.float32)
        o_ref[0] = jnp.zeros_like(q)

    logits = qk * scale - (1.0 - mask)[None, :] * BIG
    m_prev = m_ref[0, :]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None]) * mask[None, :]

    m_ref[0, :] = m_new
    l_ref[0, :] = l_ref[0, :] * alpha + p.sum(axis=1)
    o_ref[0] = o_ref[0] * alpha[:, None] + jnp.einsum(
        "hj,jhd->hd", p, v, preferred_element_type=jnp.float32
    )

    @pl.when(sb == n_blocks - 1)
    def _final():
        o_ref[0] = o_ref[0] / l_ref[0, :][:, None]


def freeze_masked_attention(q, k, v, mask, *, block_k=64, interpret=True):
    """Freeze-masked single-query attention over the KV cache.

    Args/returns as `ref.ref_decode_attention`: q [B,H,D], k/v [B,S,H,D],
    mask [B,S] -> out [B,H,D].
    """
    b, h, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    if s % bk != 0:
        raise ValueError(f"S={s} not divisible by block_k={bk}")
    n_blocks = s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, scale=scale, n_blocks=n_blocks)
    out, _m, _l = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return out
