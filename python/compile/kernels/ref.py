"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package has
a matching function here, and `python/tests/test_kernels.py` sweeps
shapes with hypothesis asserting allclose between the two.

Shapes (decode step, single query per sequence):
    q    [B, H, D]     current-token queries (RoPE already applied)
    k    [B, S, H, D]  key cache rows (RoPE already applied at write time)
    v    [B, S, H, D]  value cache rows
    mask [B, S]        1.0 = active row, 0.0 = frozen / unwritten
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_decode_attention(q, k, v, mask):
    """Freeze-masked single-query attention (paper Eq. 1 over active rows).

    Returns [B, H, D]. Rows with mask==0 receive -inf logits pre-softmax,
    i.e. they are *excluded from active attention computation* (§3.3).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    # [B, H, S]
    logits = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    logits = jnp.where(mask[:, None, :] > 0.5, logits, NEG_INF)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", w, v)


def ref_relevance(q, k, mask):
    """Paper Eq. 2: s_j = (1/H) * sum_h |q_h . k_{j,h}|, masked to 0 elsewhere.

    Returns [B, S]. Note: *un*-scaled dot product, matching the paper
    (no 1/sqrt(d) factor in Eq. 2).
    """
    s = jnp.abs(jnp.einsum("bhd,bshd->bhs", q, k)).mean(axis=1)
    return s * (mask > 0.5)


def ref_fused(q, k, v, mask):
    """Oracle for the fused hot-path kernel: (attention out, relevance)."""
    return ref_decode_attention(q, k, v, mask), ref_relevance(q, k, mask)
