"""Fused decode hot path: freeze-masked flash attention + Eq.2 relevance.

One pass over the KV cache per (batch, KV-tile) grid step computes BOTH
the attention output and the relevance scores the L3 freeze scheduler
consumes — the paper's per-token bookkeeping collapsed into a single
streamed kernel (DESIGN.md §Hardware-Adaptation).

TPU mapping:
  * the grid's second axis walks the KV cache in `block_k`-row tiles;
    the BlockSpec index maps express the HBM->VMEM stream the CUDA
    version would do with cp.async into shared memory;
  * the activity mask is a [block_k] f32 tile folded into the logits as
    an additive -1e30 *and* a multiplicative zero on the exp'd weights,
    so frozen rows are excluded branch-free (correct even for tiles
    that are entirely frozen);
  * running-softmax state (m, l, running numerator) is carried in
    revisited output blocks whose index map ignores the KV axis — the
    standard Pallas accumulation pattern; with d_head=32, H=4,
    block_k=64 the resident K+V tile is 64 KiB, far inside VMEM even
    double-buffered.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against `ref.py` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BIG = 1e30


def _fused_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, s_ref, m_ref, l_ref, *, scale, n_blocks):
    sb = pl.program_id(1)

    q = q_ref[0]          # [H, D]
    k = k_ref[0]          # [BK, H, D]
    v = v_ref[0]          # [BK, H, D]
    mask = mask_ref[0]    # [BK]

    # [H, BK] raw interaction, per head: qk[h, j] = q[h, :] . k[j, h, :]
    qk = jnp.einsum("hd,jhd->hj", q, k, preferred_element_type=jnp.float32)

    # Eq. 2 relevance for this tile (unscaled |q.k| averaged over heads)
    s_ref[0, :] = jnp.abs(qk).mean(axis=0) * mask

    @pl.when(sb == 0)
    def _init():
        m_ref[0, :] = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
        l_ref[0, :] = jnp.zeros((q.shape[0],), jnp.float32)
        o_ref[0] = jnp.zeros_like(q)

    logits = qk * scale - (1.0 - mask)[None, :] * BIG  # frozen rows -> -1e30

    m_prev = m_ref[0, :]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)                     # rescale factor for old state
    p = jnp.exp(logits - m_new[:, None]) * mask[None, :]  # [H, BK]; frozen rows exactly 0

    m_ref[0, :] = m_new
    l_ref[0, :] = l_ref[0, :] * alpha + p.sum(axis=1)
    # numerator accumulation: o[h, d] += sum_j p[h, j] * v[j, h, d]
    o_ref[0] = o_ref[0] * alpha[:, None] + jnp.einsum(
        "hj,jhd->hd", p, v, preferred_element_type=jnp.float32
    )

    @pl.when(sb == n_blocks - 1)
    def _final():
        o_ref[0] = o_ref[0] / l_ref[0, :][:, None]


def fused_decode_attention_parts(q, k, v, mask, *, block_k=64, interpret=True):
    """Fused freeze-masked attention over the cache, UNNORMALIZED.

    Returns `(acc [B,H,D], m [B,H], l [B,H], scores [B,S])` — the
    running-softmax state after the cache pass, so the caller can fold
    additional rows (the current token, computed in the same graph but
    not yet written to the cache) before normalizing:

        m2 = max(m, s_new); l2 = l*exp(m-m2) + exp(s_new-m2)
        out = (acc*exp(m-m2) + exp(s_new-m2) * v_new) / l2

    This is the hot-path variant the decode graph uses: the cache stays
    a pure input (no in-graph scatter), which removes every full-cache
    copy from the step (DESIGN.md §Perf).
    """
    b, h, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    if s % bk != 0:
        raise ValueError(f"S={s} not divisible by block_k={bk}")
    n_blocks = s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_fused_kernel_parts, scale=scale)
    acc, scores, m, l = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return acc, m, l, scores


def _fused_kernel_parts(q_ref, k_ref, v_ref, mask_ref, o_ref, s_ref, m_ref, l_ref, *, scale):
    """Same running-softmax pass as `_fused_kernel`, minus the final
    normalization (the caller merges extra rows first)."""
    sb = pl.program_id(1)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    mask = mask_ref[0]

    qk = jnp.einsum("hd,jhd->hj", q, k, preferred_element_type=jnp.float32)
    s_ref[0, :] = jnp.abs(qk).mean(axis=0) * mask

    @pl.when(sb == 0)
    def _init():
        m_ref[0, :] = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
        l_ref[0, :] = jnp.zeros((q.shape[0],), jnp.float32)
        o_ref[0] = jnp.zeros_like(q)

    logits = qk * scale - (1.0 - mask)[None, :] * BIG
    m_prev = m_ref[0, :]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None]) * mask[None, :]

    m_ref[0, :] = m_new
    l_ref[0, :] = l_ref[0, :] * alpha + p.sum(axis=1)
    o_ref[0] = o_ref[0] * alpha[:, None] + jnp.einsum(
        "hj,jhd->hd", p, v, preferred_element_type=jnp.float32
    )


def fused_decode_attention(q, k, v, mask, *, block_k=64, interpret=True):
    """Fused freeze-masked attention + relevance.

    Args:
      q:    [B, H, D] f32 — current-token queries (RoPE applied).
      k,v:  [B, S, H, D] f32 — KV cache (RoPE applied to k at write time).
      mask: [B, S] f32 — 1.0 active, 0.0 frozen/unwritten. Each sequence
            must have at least one active row (the current token is).
      block_k: KV tile rows (VMEM working-set knob).
    Returns:
      (out [B, H, D], scores [B, S]) — attention output and Eq.2 relevance.
    """
    b, h, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    if s % bk != 0:
        raise ValueError(f"S={s} not divisible by block_k={bk}")
    n_blocks = s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_fused_kernel, scale=scale, n_blocks=n_blocks)
    out, scores, _m, _l = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
            pl.BlockSpec((1, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return out, scores
