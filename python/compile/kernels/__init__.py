"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls) and are validated against the pure-jnp oracles in ref.py.
"""

from .fused import fused_decode_attention
from .freeze_attention import freeze_masked_attention
from .relevance import relevance_scores
from . import ref
