"""Standalone Eq. 2 relevance kernel.

Kept separate from the fused hot path for (a) unit-testing the relevance
math in isolation and (b) the `relevance_only` ablation in
`rust/benches/ablation_sweep.rs`, where the coordinator re-scores frozen
candidates without recomputing attention.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relevance_kernel(q_ref, k_ref, mask_ref, s_ref):
    q = q_ref[0]          # [H, D]
    k = k_ref[0]          # [BK, H, D]
    mask = mask_ref[0]    # [BK]
    qk = jnp.einsum("hd,jhd->hj", q, k, preferred_element_type=jnp.float32)
    s_ref[0, :] = jnp.abs(qk).mean(axis=0) * mask


def relevance_scores(q, k, mask, *, block_k=64, interpret=True):
    """Paper Eq. 2: s_j = (1/H) sum_h |q_h . k_{j,h}| for active rows.

    Args:
      q:    [B, H, D] f32 current-token queries.
      k:    [B, S, H, D] f32 key cache.
      mask: [B, S] f32 activity mask.
    Returns:
      scores [B, S] f32, zero on inactive rows.
    """
    b, h, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    if s % bk != 0:
        raise ValueError(f"S={s} not divisible by block_k={bk}")

    return pl.pallas_call(
        _relevance_kernel,
        grid=(b, s // bk),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.float32),
        interpret=interpret,
    )(q, k, mask)
