"""Training-loop and AOT-export tests (tiny configs — seconds, not
minutes; the real training run happens in `make artifacts`)."""

import os

import jax
import numpy as np
import pytest

from compile.config import ModelConfig, TrainConfig
from compile.aot import lower_decode, lower_prefill, to_hlo_text
from compile.model import init_params
from compile.train import load_params, save_params, train

TINY = ModelConfig(vocab=256, d_model=32, n_layers=2, n_heads=2, d_head=16,
                   d_ff=64, max_len=64)


def test_train_reduces_loss():
    tc = TrainConfig(seq_len=64, batch=4, steps=30, warmup=5, seed=0)
    _, log = train(TINY, tc)
    assert log[0]["loss"] > log[-1]["loss"] + 0.5, log


def test_params_save_load_roundtrip(tmp_path):
    params = init_params(jax.random.PRNGKey(1), TINY)
    path = str(tmp_path / "p.npz")
    save_params(params, path)
    loaded = load_params(path, TINY)
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(loaded)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lower_decode_emits_full_hlo():
    params = init_params(jax.random.PRNGKey(0), TINY)
    text = to_hlo_text(lower_decode(params, TINY, 1, 64, 32))
    assert "ENTRY" in text
    # the printer must NOT elide weights (the bug this guards against:
    # default as_hlo_text drops large constants as `{...}`)
    assert "{...}" not in text
    # entry has exactly the 4 dynamic params (token, kv, mask, pos)
    assert "parameter(3)" in text


def test_lower_prefill_emits_full_hlo():
    params = init_params(jax.random.PRNGKey(0), TINY)
    text = to_hlo_text(lower_prefill(params, TINY, 1, 32))
    assert "ENTRY" in text
    assert "{...}" not in text


@pytest.mark.skipif(not os.path.exists("../artifacts/manifest.json"),
                    reason="run `make artifacts` first")
def test_manifest_consistent_with_artifacts():
    import json
    with open("../artifacts/manifest.json") as f:
        manifest = json.load(f)
    assert manifest["model"]["kv_row_floats"] == (
        manifest["model"]["n_layers"] * 2 * manifest["model"]["n_heads"]
        * manifest["model"]["d_head"]
    )
    for name, prog in manifest["programs"].items():
        path = os.path.join("../artifacts", prog["file"])
        assert os.path.exists(path), f"{name}: missing {path}"
        assert os.path.getsize(path) > 1_000_000, f"{name}: suspiciously small HLO"
