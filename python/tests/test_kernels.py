"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes, mask densities and magnitudes; this is the
core correctness signal for the decode hot path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused import fused_decode_attention
from compile.kernels.freeze_attention import freeze_masked_attention
from compile.kernels.relevance import relevance_scores
from compile.kernels.ref import ref_decode_attention, ref_fused, ref_relevance

ATOL = 2e-5


def _mk(rng, b, s, h, d, density, scale=1.0):
    q = jnp.asarray(rng.normal(size=(b, h, d)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)) * scale, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)) * scale, jnp.float32)
    mask = (rng.random((b, s)) < density).astype(np.float32)
    mask[:, 0] = 1.0  # at least one active row per sequence
    return q, k, v, jnp.asarray(mask)


shape_strategy = st.tuples(
    st.integers(1, 4),                      # B
    st.sampled_from([64, 128, 192, 256]),   # S (multiple of block)
    st.integers(1, 4),                      # H
    st.sampled_from([8, 16, 32]),           # D
    st.floats(0.05, 1.0),                   # mask density
    st.integers(0, 2 ** 31 - 1),            # seed
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_fused_matches_ref(args):
    b, s, h, d, density, seed = args
    rng = np.random.default_rng(seed)
    q, k, v, mask = _mk(rng, b, s, h, d, density)
    o_ref, s_ref = ref_fused(q, k, v, mask)
    o, sc = fused_decode_attention(q, k, v, mask, block_k=64)
    np.testing.assert_allclose(o, o_ref, atol=ATOL)
    np.testing.assert_allclose(sc, s_ref, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(shape_strategy)
def test_unfused_attention_matches_ref(args):
    b, s, h, d, density, seed = args
    rng = np.random.default_rng(seed)
    q, k, v, mask = _mk(rng, b, s, h, d, density)
    out = freeze_masked_attention(q, k, v, mask, block_k=64)
    np.testing.assert_allclose(out, ref_decode_attention(q, k, v, mask), atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(shape_strategy)
def test_relevance_matches_ref(args):
    b, s, h, d, density, seed = args
    rng = np.random.default_rng(seed)
    q, k, _, mask = _mk(rng, b, s, h, d, density)
    sc = relevance_scores(q, k, mask, block_k=64)
    np.testing.assert_allclose(sc, ref_relevance(q, k, mask), atol=ATOL)


@pytest.mark.parametrize("block_k", [16, 32, 64, 128])
def test_block_size_invariance(block_k):
    rng = np.random.default_rng(7)
    q, k, v, mask = _mk(rng, 2, 128, 4, 32, 0.5)
    o_ref, s_ref = ref_fused(q, k, v, mask)
    o, sc = fused_decode_attention(q, k, v, mask, block_k=block_k)
    np.testing.assert_allclose(o, o_ref, atol=ATOL)
    np.testing.assert_allclose(sc, s_ref, atol=ATOL)


def test_single_active_row_attends_only_there():
    """With exactly one active row, attention output == that row's value."""
    rng = np.random.default_rng(3)
    b, s, h, d = 2, 128, 4, 32
    q, k, v, _ = _mk(rng, b, s, h, d, 1.0)
    mask = np.zeros((b, s), np.float32)
    mask[:, 17] = 1.0
    out, _ = fused_decode_attention(q, k, v, jnp.asarray(mask))
    np.testing.assert_allclose(out, v[:, 17], atol=ATOL)


def test_frozen_rows_do_not_influence_output():
    """Changing the contents of masked rows must not change the output."""
    rng = np.random.default_rng(11)
    q, k, v, mask = _mk(rng, 2, 128, 4, 32, 0.4)
    o1, s1 = fused_decode_attention(q, k, v, mask)
    noise = jnp.asarray(rng.normal(size=k.shape) * 100, jnp.float32)
    inactive = (1.0 - mask)[:, :, None, None]
    o2, s2 = fused_decode_attention(k=k + noise * inactive, v=v + noise * inactive, q=q, mask=mask)
    np.testing.assert_allclose(o1, o2, atol=ATOL)
    np.testing.assert_allclose(s1, s2, atol=ATOL)


def test_all_active_equals_plain_softmax_attention():
    rng = np.random.default_rng(5)
    b, s, h, d = 1, 64, 2, 16
    q, k, v, _ = _mk(rng, b, s, h, d, 1.0)
    mask = jnp.ones((b, s), jnp.float32)
    out, _ = fused_decode_attention(q, k, v, mask)
    scale = 1.0 / np.sqrt(d)
    logits = np.einsum("bhd,bshd->bhs", q, k) * scale
    w = np.exp(logits - logits.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    expected = np.einsum("bhs,bshd->bhd", w, v)
    np.testing.assert_allclose(out, expected, atol=ATOL)


def test_relevance_is_unscaled_and_nonnegative():
    rng = np.random.default_rng(9)
    q, k, _, mask = _mk(rng, 2, 64, 4, 32, 0.7)
    sc = relevance_scores(q, k, mask)
    assert (np.asarray(sc) >= 0).all()
    # frozen rows must score exactly 0
    assert np.all(np.asarray(sc)[np.asarray(mask) == 0] == 0)


def test_large_magnitude_stability():
    """Running softmax must stay finite with large logits."""
    rng = np.random.default_rng(13)
    q, k, v, mask = _mk(rng, 1, 128, 2, 16, 0.5, scale=30.0)
    out, sc = fused_decode_attention(q, k, v, mask)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(sc)).all()
    np.testing.assert_allclose(out, ref_decode_attention(q, k, v, mask), atol=1e-3)


def test_rejects_non_divisible_s():
    rng = np.random.default_rng(1)
    q, k, v, mask = _mk(rng, 1, 96, 2, 16, 1.0)
    with pytest.raises(ValueError):
        fused_decode_attention(q, k, v, mask, block_k=64)


# ---------------------------------------------------------------------------
# Unnormalized "parts" variant (the AOT hot path)

from compile.kernels.fused import fused_decode_attention_parts


@settings(max_examples=15, deadline=None)
@given(shape_strategy)
def test_parts_recompose_to_full_attention(args):
    b, s, h, d, density, seed = args
    rng = np.random.default_rng(seed)
    q, k, v, mask = _mk(rng, b, s, h, d, density)
    acc, m, l, scores = fused_decode_attention_parts(q, k, v, mask, block_k=64)
    out = np.asarray(acc) / np.asarray(l)[..., None]
    o_ref, s_ref = ref_fused(q, k, v, mask)
    np.testing.assert_allclose(out, o_ref, atol=ATOL)
    np.testing.assert_allclose(scores, s_ref, atol=ATOL)


def test_parts_fold_extra_row_equals_full_attention():
    """Folding one extra row into (acc, m, l) must equal attention over
    the cache WITH that row present and active — the exact identity the
    decode graph relies on for the current token."""
    rng = np.random.default_rng(17)
    b, s, h, d = 2, 128, 4, 32
    q, k, v, mask = _mk(rng, b, s, h, d, 0.6)
    # reserve slot 5 (inactive in EVERY batch row) for the folded row
    mask = mask.at[:, 5].set(0.0)
    k_new = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)

    acc, m, l, _ = fused_decode_attention_parts(q, k, v, mask, block_k=64)
    scale = 1.0 / np.sqrt(d)
    s_new = jnp.einsum("bhd,bhd->bh", q, k_new) * scale
    m2 = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m2)
    p_new = jnp.exp(s_new - m2)
    l2 = l * alpha + p_new
    out = (acc * alpha[..., None] + p_new[..., None] * v_new) / l2[..., None]

    # reference: put the row at the reserved masked slot and activate it
    slot = 5
    k2 = k.at[:, slot].set(k_new)
    v2 = v.at[:, slot].set(v_new)
    mask2 = mask.at[:, slot].set(1.0)
    expected = ref_decode_attention(q, k2, v2, mask2)
    np.testing.assert_allclose(out, expected, atol=1e-4)
