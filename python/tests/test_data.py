"""Synthetic corpus + passkey curriculum tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.data import (
    batch_iterator, filler, make_passkey_prompt, passkey_sample, prose, sentence,
)


def test_sentence_structure():
    rng = np.random.default_rng(0)
    for _ in range(50):
        s = sentence(rng)
        assert s.endswith(". ")
        assert s.islower() or any(c.isdigit() for c in s) or True
        assert len(s.split()) >= 3


@settings(max_examples=20, deadline=None)
@given(st.integers(50, 2000), st.integers(0, 2**31 - 1))
def test_prose_exact_length(n, seed):
    rng = np.random.default_rng(seed)
    assert len(prose(rng, n)) == n


@settings(max_examples=20, deadline=None)
@given(st.integers(120, 1500), st.integers(0, 2**31 - 1))
def test_passkey_sample_contains_key_twice(seq_len, seed):
    rng = np.random.default_rng(seed)
    s = passkey_sample(rng, seq_len, key="31415")
    assert s.count(b"31415") == 2, s
    assert s.startswith(b"the pass key is 31415")
    assert len(s) <= seq_len


def test_passkey_prompt_withholds_answer():
    rng = np.random.default_rng(3)
    p = make_passkey_prompt(rng, 500, "98765")
    # needle appears once (at the start), never after the query
    assert p.count(b"98765") == 1
    assert p.endswith(b"what is the pass key? the pass key is ")


def test_batch_iterator_shapes_and_determinism():
    it1 = batch_iterator(7, batch=4, seq_len=128, passkey_frac=0.5)
    it2 = batch_iterator(7, batch=4, seq_len=128, passkey_frac=0.5)
    for _ in range(3):
        a, b = next(it1), next(it2)
        assert a.shape == (4, 128)
        assert a.dtype == np.uint8
        np.testing.assert_array_equal(a, b)


def test_batch_iterator_mixes_tasks():
    it = batch_iterator(1, batch=8, seq_len=256, passkey_frac=0.5)
    batch = next(it)
    texts = [bytes(row) for row in batch]
    with_key = sum(b"pass key" in t for t in texts)
    assert 0 < with_key < 8, f"{with_key} passkey rows of 8"
