"""L2 model consistency: decode path == training-time causal forward.

The strongest available oracle: running `decode_apply` step by step with
an all-active mask must reproduce exactly the logits that the plain
causal `train_forward` produces on the same (growing) sequence, and
`prefill_apply` must agree with both. Also covers the freeze/restore
row-transfer semantics of the decode graph.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig
from compile.model import (
    decode_apply, init_params, prefill_apply, train_forward,
)

CFG = ModelConfig(vocab=256, d_model=32, n_layers=2, n_heads=2, d_head=16,
                  d_ff=64, max_len=64)
R = 4  # freeze/restore budget used in tests
ATOL = 1e-4


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _empty_kv(b, s):
    return jnp.zeros((CFG.n_layers, 2, b, s, CFG.n_heads, CFG.d_head), jnp.float32)


def _no_transfer(b, s):
    """Padded (inert) freeze/restore inputs: index S is dropped by the graph."""
    idx = jnp.full((b, R), s, jnp.int32)
    rows = jnp.zeros((b, R, CFG.n_layers, 2, CFG.n_heads, CFG.d_head), jnp.float32)
    return idx, rows, idx


def _decode(params, token, kv, mask, pos, ri=None, rr=None, fi=None):
    b, s = mask.shape
    d_ri, d_rr, d_fi = _no_transfer(b, s)
    return decode_apply(
        params, CFG, token, kv, mask, pos,
        d_ri if ri is None else ri,
        d_rr if rr is None else rr,
        d_fi if fi is None else fi,
        block_k=32,
    )


def test_decode_matches_causal_forward(params):
    """Greedy decode via decode_apply == train_forward on the full prefix."""
    rng = np.random.default_rng(0)
    b, s, prompt_len, n_steps = 1, 64, 5, 6
    tokens = rng.integers(32, 127, size=prompt_len).tolist()

    kv = _empty_kv(b, s)
    mask = jnp.zeros((b, s), jnp.float32)
    logits = None
    for i, t in enumerate(tokens + [0] * (n_steps - 1)):
        if i >= prompt_len:
            t = int(jnp.argmax(logits[0]))
            tokens.append(t)
        logits, kv, scores, _ = _decode(
            params, jnp.asarray([t], jnp.int32), kv, mask, jnp.asarray([i], jnp.int32))
        mask = mask.at[0, i].set(1.0)

    full = train_forward(params, CFG, jnp.asarray([tokens], jnp.int32))
    np.testing.assert_allclose(logits[0], full[0, -1], atol=ATOL)


def test_prefill_matches_causal_forward(params):
    rng = np.random.default_rng(1)
    l = 16
    tokens = jnp.asarray(rng.integers(32, 127, size=(1, l)), jnp.int32)
    logits_last, kv, scores_last = prefill_apply(params, CFG, tokens, jnp.asarray([l], jnp.int32))
    full = train_forward(params, CFG, tokens)
    np.testing.assert_allclose(logits_last[0], full[0, -1], atol=ATOL)
    assert kv.shape == (CFG.n_layers, 2, 1, l, CFG.n_heads, CFG.d_head)
    assert (np.asarray(scores_last) >= 0).all()


def test_prefill_padding_invariance(params):
    """Padding the prompt must not change last-position logits or KV rows."""
    rng = np.random.default_rng(2)
    l = 10
    tokens = rng.integers(32, 127, size=(1, l))
    t1 = jnp.asarray(tokens, jnp.int32)
    t2 = jnp.asarray(np.pad(tokens, ((0, 0), (0, 6)), constant_values=32), jnp.int32)
    len_arr = jnp.asarray([l], jnp.int32)
    lo1, kv1, sc1 = prefill_apply(params, CFG, t1, len_arr)
    lo2, kv2, sc2 = prefill_apply(params, CFG, t2, len_arr)
    np.testing.assert_allclose(lo1, lo2, atol=ATOL)
    np.testing.assert_allclose(kv1, kv2[:, :, :, :l], atol=ATOL)
    np.testing.assert_allclose(sc1, sc2[:, :l], atol=ATOL)


def test_prefill_then_decode_consistent(params):
    """prefill_apply + one decode step == train_forward on prompt+1."""
    rng = np.random.default_rng(3)
    b, s, l = 1, 64, 12
    tokens = rng.integers(32, 127, size=(1, l))
    logits_last, kv_rows, _ = prefill_apply(
        params, CFG, jnp.asarray(tokens, jnp.int32), jnp.asarray([l], jnp.int32))
    nxt = int(np.argmax(logits_last[0]))

    kv = _empty_kv(b, s).at[:, :, :, :l].set(kv_rows)
    mask = jnp.zeros((b, s), jnp.float32).at[0, :l].set(1.0)
    logits, _, _, _ = _decode(
        params, jnp.asarray([nxt], jnp.int32), kv, mask, jnp.asarray([l], jnp.int32))

    seq = np.concatenate([tokens, [[nxt]]], axis=1)
    full = train_forward(params, CFG, jnp.asarray(seq, jnp.int32))
    np.testing.assert_allclose(logits[0], full[0, -1], atol=ATOL)


def test_freeze_gather_returns_rows_and_zeroes_cache(params):
    rng = np.random.default_rng(4)
    b, s = 1, 64
    kv = jnp.asarray(rng.normal(size=_empty_kv(b, s).shape), jnp.float32)
    mask = jnp.ones((b, s), jnp.float32)
    fi = jnp.asarray([[3, 10, s, s]], jnp.int32)  # freeze rows 3 and 10
    _, kv_out, _, frozen = _decode(
        params, jnp.asarray([65], jnp.int32), kv, mask, jnp.asarray([20], jnp.int32), fi=fi)

    # gathered contents match the original cache rows
    np.testing.assert_allclose(frozen[0, 0], kv[:, :, 0, 3], atol=ATOL)
    np.testing.assert_allclose(frozen[0, 1], kv[:, :, 0, 10], atol=ATOL)
    # padded slots are zero
    assert np.all(np.asarray(frozen[0, 2:]) == 0)
    # frozen rows are zeroed in the cache that comes back
    assert np.all(np.asarray(kv_out[:, :, 0, 3]) == 0)
    assert np.all(np.asarray(kv_out[:, :, 0, 10]) == 0)
    # untouched row survives
    np.testing.assert_allclose(kv_out[:, :, 0, 5], kv[:, :, 0, 5], atol=ATOL)


def test_restore_scatter_writes_rows(params):
    rng = np.random.default_rng(5)
    b, s = 1, 64
    kv = _empty_kv(b, s)
    mask = jnp.ones((b, s), jnp.float32)
    rows = jnp.asarray(
        rng.normal(size=(b, R, CFG.n_layers, 2, CFG.n_heads, CFG.d_head)), jnp.float32)
    ri = jnp.asarray([[7, 9, s, s]], jnp.int32)
    _, kv_out, _, _ = _decode(
        params, jnp.asarray([65], jnp.int32), kv, mask, jnp.asarray([20], jnp.int32),
        ri=ri, rr=rows)
    np.testing.assert_allclose(kv_out[:, :, 0, 7], rows[0, 0], atol=ATOL)
    np.testing.assert_allclose(kv_out[:, :, 0, 9], rows[0, 1], atol=ATOL)


def test_freeze_restore_roundtrip_preserves_rows(params):
    """Freeze rows at step i, restore the stashed payload at step i+1:
    the cache rows must come back bit-identical (reversibility, §3.3)."""
    rng = np.random.default_rng(6)
    b, s = 1, 64
    kv = jnp.asarray(rng.normal(size=_empty_kv(b, s).shape), jnp.float32)
    mask = jnp.ones((b, s), jnp.float32)
    fi = jnp.asarray([[2, 5, 11, s]], jnp.int32)
    _, kv1, _, frozen = _decode(
        params, jnp.asarray([65], jnp.int32), kv, mask, jnp.asarray([20], jnp.int32), fi=fi)
    _, kv2, _, _ = _decode(
        params, jnp.asarray([66], jnp.int32), kv1, mask, jnp.asarray([21], jnp.int32),
        ri=fi, rr=frozen)
    for r in [2, 5, 11]:
        np.testing.assert_allclose(kv2[:, :, 0, r], kv[:, :, 0, r], atol=ATOL)


def test_masked_decode_ignores_frozen_rows(params):
    """Logits with (frozen rows zeroed + mask 0) == logits with those rows
    never having existed in the active set."""
    rng = np.random.default_rng(7)
    b, s, l = 1, 64, 16
    tokens = jnp.asarray(rng.integers(32, 127, size=(1, l)), jnp.int32)
    _, kv_rows, _ = prefill_apply(params, CFG, tokens, jnp.asarray([l], jnp.int32))
    kv = _empty_kv(b, s).at[:, :, :, :l].set(kv_rows)

    frozen_set = [4, 7, 8]
    mask = jnp.zeros((b, s), jnp.float32).at[0, :l].set(1.0)
    for f in frozen_set:
        mask = mask.at[0, f].set(0.0)

    # variant A: rows present but masked
    lo_a, _, sc_a, _ = _decode(
        params, jnp.asarray([65], jnp.int32), kv, mask, jnp.asarray([l], jnp.int32))
    # variant B: rows additionally zeroed (as the freeze path does)
    kv_b = kv
    for f in frozen_set:
        kv_b = kv_b.at[:, :, 0, f].set(0.0)
    lo_b, _, sc_b, _ = _decode(
        params, jnp.asarray([65], jnp.int32), kv_b, mask, jnp.asarray([l], jnp.int32))
    np.testing.assert_allclose(lo_a, lo_b, atol=ATOL)
    np.testing.assert_allclose(sc_a, sc_b, atol=ATOL)


def test_batched_decode_matches_single(params):
    """Each sequence in a batch evolves as if decoded alone."""
    rng = np.random.default_rng(8)
    b, s, l = 3, 64, 8
    toks = rng.integers(32, 127, size=(b, l))
    kv_b = _empty_kv(b, s)
    mask_b = jnp.zeros((b, s), jnp.float32)
    for i in range(l):
        lo_b, kv_b, _, _ = _decode(
            params, jnp.asarray(toks[:, i], jnp.int32), kv_b, mask_b,
            jnp.full((b,), i, jnp.int32))
        mask_b = mask_b.at[:, i].set(1.0)

    for seq in range(b):
        kv1 = _empty_kv(1, s)
        mask1 = jnp.zeros((1, s), jnp.float32)
        for i in range(l):
            lo1, kv1, _, _ = _decode(
                params, jnp.asarray([toks[seq, i]], jnp.int32), kv1, mask1,
                jnp.asarray([i], jnp.int32))
            mask1 = mask1.at[0, i].set(1.0)
        np.testing.assert_allclose(lo_b[seq], lo1[0], atol=ATOL)


# ---------------------------------------------------------------------------
# Pure decode_step (the AOT-exported hot path): cache is read-only; the
# current token's row is folded in-kernel before normalization.

from compile.model import decode_step


def _write_row(kv, pos, k_new, v_new):
    """Engine-side row write: k_new/v_new [nl,B,H,D] -> kv at pos."""
    return kv.at[:, 0, :, pos].set(k_new).at[:, 1, :, pos].set(v_new)


def test_decode_step_matches_causal_forward(params):
    rng = np.random.default_rng(20)
    b, s, prompt_len, n_steps = 1, 64, 5, 6
    tokens = rng.integers(32, 127, size=prompt_len).tolist()

    kv = _empty_kv(b, s)
    mask = jnp.zeros((b, s), jnp.float32)
    logits = None
    for i, t in enumerate(tokens + [0] * (n_steps - 1)):
        if i >= prompt_len:
            t = int(jnp.argmax(logits[0]))
            tokens.append(t)
        logits, k_new, v_new, scores = decode_step(
            params, CFG, jnp.asarray([t], jnp.int32), kv, mask,
            jnp.asarray([i], jnp.int32), block_k=32)
        kv = _write_row(kv, i, k_new, v_new)
        mask = mask.at[0, i].set(1.0)

    full = train_forward(params, CFG, jnp.asarray([tokens], jnp.int32))
    np.testing.assert_allclose(logits[0], full[0, -1], atol=ATOL)


def test_decode_step_agrees_with_stateful_decode_apply(params):
    """The pure and stateful decode variants must produce identical
    logits/scores given equivalent state."""
    rng = np.random.default_rng(21)
    b, s, l = 1, 64, 12
    tokens = jnp.asarray(rng.integers(32, 127, size=(1, l)), jnp.int32)
    _, kv_rows, _ = prefill_apply(params, CFG, tokens, jnp.asarray([l], jnp.int32))
    kv = _empty_kv(b, s).at[:, :, :, :l].set(kv_rows)
    mask = jnp.zeros((b, s), jnp.float32).at[0, :l].set(1.0)
    tok = jnp.asarray([65], jnp.int32)
    pos = jnp.asarray([l], jnp.int32)

    lo_pure, k_new, v_new, sc_pure = decode_step(params, CFG, tok, kv, mask, pos, block_k=32)
    lo_state, kv_out, sc_state, _ = _decode(params, tok, kv, mask, pos)
    np.testing.assert_allclose(lo_pure, lo_state, atol=ATOL)
    # stateful variant wrote the row in-graph; pure variant returns it
    np.testing.assert_allclose(
        _write_row(kv, l, k_new, v_new), kv_out, atol=ATOL)
    # scores: stateful includes the just-written row's column at pos
    np.testing.assert_allclose(sc_pure[:, :l], sc_state[:, :l], atol=ATOL)


def test_decode_step_ignores_masked_row_content(params):
    rng = np.random.default_rng(22)
    b, s = 1, 64
    kv = jnp.asarray(rng.normal(size=_empty_kv(b, s).shape), jnp.float32)
    mask = jnp.ones((b, s), jnp.float32).at[0, 7].set(0.0).at[0, 33].set(0.0)
    tok = jnp.asarray([65], jnp.int32)
    pos = jnp.asarray([40], jnp.int32)
    # also mask everything beyond len=40
    mask = mask * (jnp.arange(s)[None, :] < 40)

    lo1, _, _, sc1 = decode_step(params, CFG, tok, kv, mask, pos, block_k=32)
    noise = jnp.asarray(rng.normal(size=kv.shape) * 50, jnp.float32)
    inactive = (1.0 - mask)[None, None, :, :, None, None]
    lo2, _, _, sc2 = decode_step(params, CFG, tok, kv + noise * inactive, mask, pos, block_k=32)
    np.testing.assert_allclose(lo1, lo2, atol=1e-4)
    np.testing.assert_allclose(sc1, sc2, atol=1e-4)
    assert float(sc1[0, 7]) == 0.0 and float(sc1[0, 33]) == 0.0
