//! Generation-quality comparison (paper §4.4 / Table 3): the same
//! explanation-style prompt under Full KV and ASR-KF-EGR with identical
//! sampling parameters; reports active-KV compression and an entropy-
//! based fluency proxy alongside both outputs.
//!
//!     cargo run --release --example explanation_compare

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let cfg = EngineConfig::default();
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let gen = Generator::new(&rt, cfg.clone());

    let prompt = "the recovery ladder monitors the entropy trace. the scheduler freezes \
                  the key value pairs then the engine restores the frozen rows. ";
    let max_new = 220;

    let mut table = Table::new(
        "Explanation task comparison (T=0.7, top-k=40, top-p=0.9)",
        &["Metric", "Baseline (full)", "ASR-KF-EGR"],
    );
    let mut outs = Vec::new();
    for policy in ["full", "asrkf"] {
        let out = gen.generate(prompt, make_policy(policy, &cfg.freeze)?, max_new)?;
        outs.push(out);
    }
    let mean_entropy = |o: &asrkf::engine::GenOutcome| {
        o.trace.iter().map(|t| t.entropy as f64).sum::<f64>() / o.trace.len() as f64
    };
    table.row(&[
        "Active KV".into(),
        format!("{} tokens", outs[0].stats.final_active_kv),
        format!("{} tokens", outs[1].stats.final_active_kv),
    ]);
    table.row(&[
        "Compression".into(),
        format!("{:.2}%", outs[0].stats.compression * 100.0),
        format!("{:.2}%", outs[1].stats.compression * 100.0),
    ]);
    table.row(&[
        "Mean entropy (nats)".into(),
        format!("{:.3}", mean_entropy(&outs[0])),
        format!("{:.3}", mean_entropy(&outs[1])),
    ]);
    table.row(&[
        "Wall time".into(),
        format!("{:.2?}", outs[0].stats.wall),
        format!("{:.2?}", outs[1].stats.wall),
    ]);
    table.print();

    println!("\n--- baseline output ---\n{}", outs[0].text);
    println!("\n--- ASR-KF-EGR output ---\n{}", outs[1].text);
    Ok(())
}
