//! End-to-end serving driver (the repo's E2E validation workload):
//! spawns the continuous-batching coordinator in-process, submits a
//! Poisson trace of requests against it, and reports latency and
//! throughput — all through the public API.
//!
//!     cargo run --release --example serving_benchmark

use std::time::{Duration, Instant};

use asrkf::config::{EngineConfig, ServerConfig};
use asrkf::coordinator::{spawn, GenParams};
use asrkf::util::bench::Table;
use asrkf::workload::trace::poisson_trace;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server)?;

    // Poisson arrivals: 24 requests, ~3 req/s, short generations
    let trace = poisson_trace(42, 24, 3.0, 40, 120, 32);
    let t0 = Instant::now();
    let mut waits = Vec::new();
    for req in &trace {
        let target = Duration::from_millis(req.arrival_ms);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let ticket = handle.submit(
            GenParams::builder(req.prompt.clone())
                .max_new(req.max_new)
                .seed(req.arrival_ms)
                .build(),
        )?;
        waits.push((req.arrival_ms, ticket));
    }

    let mut table = Table::new(
        "Serving benchmark (continuous batching, ASR-KF-EGR)",
        &["req", "prompt_toks", "gen_toks", "ttft_ms", "e2e_ms", "compression"],
    );
    let mut total_tokens = 0usize;
    let (mut ttft_sum, mut e2e_sum) = (0.0f64, 0.0f64);
    let n = waits.len();
    for (i, (_, ticket)) in waits.into_iter().enumerate() {
        let resp = ticket.wait()?;
        if let Some(e) = &resp.error {
            println!("request {i} failed: {e}");
            continue;
        }
        total_tokens += resp.generated_tokens;
        ttft_sum += resp.ttft.as_secs_f64() * 1000.0;
        e2e_sum += resp.e2e.as_secs_f64() * 1000.0;
        table.row(&[
            format!("{i}"),
            resp.prompt_tokens.to_string(),
            resp.generated_tokens.to_string(),
            format!("{:.1}", resp.ttft.as_secs_f64() * 1000.0),
            format!("{:.1}", resp.e2e.as_secs_f64() * 1000.0),
            format!("{:.1}%", resp.compression * 100.0),
        ]);
    }
    let wall = t0.elapsed();
    table.print();
    println!(
        "\n{} requests, {} tokens in {:.2?} -> {:.1} tok/s (mean ttft {:.0} ms, mean e2e {:.0} ms)",
        n,
        total_tokens,
        wall,
        total_tokens as f64 / wall.as_secs_f64(),
        ttft_sum / n as f64,
        e2e_sum / n as f64,
    );

    drop(handle); // disconnect -> coordinator drains and exits
    let _ = join.join();
    Ok(())
}
