//! Quickstart: load the AOT artifacts, generate text under the
//! ASR-KF-EGR policy, and print the memory-compression stats.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Python is NOT involved: the model weights live inside
//! `artifacts/*.hlo.txt`, loaded and executed through PJRT.

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();

    // 1. load the runtime (compiles HLO programs on first use)
    let cfg = EngineConfig::default();
    let rt = Runtime::load(&cfg.artifacts_dir)?;

    // 2. build the paper's policy (swap "asrkf" for "full", "h2o" or
    //    "streaming" to compare)
    let policy = make_policy("asrkf", &cfg.freeze)?;

    // 3. generate
    let gen = Generator::new(&rt, cfg);
    let prompt = "the router balances every request then the cache freezes the key value pairs. ";
    let out = gen.generate(prompt, policy, 160)?;

    println!("prompt : {prompt}");
    println!("output : {}", out.text);
    println!();
    println!(
        "tokens {} | active KV {} | mean active {:.0} | compression {:.1}% | {} freezes, {} restores",
        out.stats.total_tokens,
        out.stats.final_active_kv,
        out.stats.mean_active_kv,
        out.stats.compression * 100.0,
        out.stats.freezes,
        out.stats.restores,
    );
    println!(
        "wall {:.2?} (upload {:.2?} execute {:.2?} download {:.2?} host {:.2?})",
        out.stats.wall, out.stats.upload, out.stats.execute, out.stats.download, out.stats.host
    );
    Ok(())
}
