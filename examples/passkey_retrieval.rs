//! Needle-in-haystack retrieval (paper §4.3 / Table 2) across KV
//! policies: the reversible freeze keeps the needle recoverable, while
//! irreversible baselines (StreamingLLM) lose it once it leaves the
//! window.
//!
//!     cargo run --release --example passkey_retrieval

use asrkf::config::EngineConfig;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;
use asrkf::workload::passkey::run_passkey;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let cfg = EngineConfig::default();
    let rt = Runtime::load(&cfg.artifacts_dir)?;

    let haystack = 600; // bytes of filler around the needle
    let mut table = Table::new(
        "Passkey retrieval (greedy decoding, T = 0)",
        &["Method", "Target", "Retrieved", "E2E", "Needle recoverable", "Active KV", "Compression"],
    );
    for policy in ["full", "asrkf", "h2o", "streaming"] {
        let o = run_passkey(&rt, &cfg, policy, haystack, 1)?;
        table.row(&[
            policy.to_string(),
            o.target.clone(),
            o.retrieved.clone(),
            if o.pass { "PASS".into() } else { "FAIL".into() },
            format!(
                "{:.0}% -> {}",
                o.needle_recoverable * 100.0,
                if o.needle_recoverable == 1.0 { "PASS" } else { "FAIL" }
            ),
            format!("{}/{}", o.stats.final_active_kv, o.stats.total_tokens),
            format!("{:.1}%", o.stats.compression * 100.0),
        ]);
    }
    table.print();
    Ok(())
}
